"""Optimizer, checkpointing, trainer loop, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as C
from repro.train import compression as GC
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   clip_by_global_norm, init_opt_state,
                                   schedule_lr)


def test_wsd_schedule_phases():
    cfg = OptimizerConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                          total_steps=100, decay_frac=0.2, min_lr_frac=0.1)
    lr = lambda s: float(schedule_lr(cfg, jnp.int32(s)))  # noqa: E731
    assert lr(0) == pytest.approx(0.0)
    assert lr(5) == pytest.approx(0.5)          # warmup
    assert lr(10) == pytest.approx(1.0)
    assert lr(50) == pytest.approx(1.0)          # stable plateau
    assert lr(79) == pytest.approx(1.0, abs=0.06)
    assert lr(90) == pytest.approx(0.55, abs=0.02)  # mid decay
    assert lr(100) == pytest.approx(0.1, abs=0.01)  # floor


def test_cosine_schedule_monotone_decay():
    cfg = OptimizerConfig(lr=1.0, schedule="cosine", warmup_steps=5, total_steps=50)
    lrs = [float(schedule_lr(cfg, jnp.int32(s))) for s in range(5, 51, 5)]
    assert all(a >= b - 1e-6 for a, b in zip(lrs, lrs[1:]))


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_adamw_converges_quadratic():
    """AdamW should minimize a simple quadratic — catches sign/bias bugs."""
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    cfg = OptimizerConfig(lr=0.1, weight_decay=0.0, grad_clip=100.0,
                          schedule="const", warmup_steps=1)
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(cfg, g, opt, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_adamw_bf16_state_roundtrip():
    params = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    opt = init_opt_state(params, dtype=jnp.bfloat16)
    cfg = OptimizerConfig(lr=1e-2)
    g = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    p2, o2, _ = adamw_update(cfg, g, opt, params)
    assert p2["w"].dtype == jnp.bfloat16
    assert o2["m"]["w"].dtype == jnp.bfloat16


# --- checkpoint ---------------------------------------------------------


def _tiny_state():
    k = jax.random.PRNGKey(0)
    params = {"emb": {"table": jax.random.normal(k, (8, 4))},
              "units": {"w": jax.random.normal(k, (3, 4, 4))}}
    return params, init_opt_state(params)


def test_checkpoint_roundtrip():
    params, opt = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 7, params=params, opt_state=opt, extra={"note": "x"})
        like = {"params": params, "opt_state": opt}
        out = C.restore(d, 7, like=like)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b)),
                     out["params"], params)
        assert out["step"] == 7
        assert out["extra"]["note"] == "x"


def test_checkpoint_retention_and_latest():
    params, opt = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            C.save(d, s, params=params, opt_state=opt, keep=2)
        assert C.available_steps(d) == [3, 4]
        out = C.restore_latest(d, like={"params": params, "opt_state": opt})
        assert out["step"] == 4


def test_checkpoint_atomicity_no_tmp_left():
    params, opt = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 1, params=params, opt_state=opt)
        assert not any(f.endswith(".tmp") for f in os.listdir(d))


def test_elastic_restore_reshard():
    """Restore a checkpoint and re-shard onto a (1-device) different mesh —
    the elastic path; on a pod the same call re-shards onto survivors."""
    from repro.train.elastic import choose_mesh_shape, make_mesh_from_devices, remesh_state
    params, opt = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 3, params=params, opt_state=opt)
        out = C.restore(d, 3, like={"params": params, "opt_state": opt})
        shape = choose_mesh_shape(len(jax.devices()))
        mesh = make_mesh_from_devices(jax.devices(), shape)
        state = remesh_state(out, params, mesh)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b)),
                     state["params"], params)


def test_choose_mesh_shape_degrades():
    from repro.train.elastic import choose_mesh_shape
    assert choose_mesh_shape(256) == (16, 16)
    assert choose_mesh_shape(240, prefer_model=16) == (15, 16)
    assert choose_mesh_shape(7) == (1, 7)


# --- straggler detection ---------------------------------------------------


def test_heartbeat_flags_stragglers():
    from repro.train.elastic import ElasticPolicy, Heartbeat
    hb = Heartbeat(factor=3.0)
    for s in range(10):
        hb.beat(s, 0.1)
    assert not hb.is_straggling()
    hb.beat(10, 0.9)
    assert hb.is_straggling()
    pol = ElasticPolicy(tolerate_flags=3)
    for s in (11, 12):
        hb.beat(s, 0.9)
    assert pol.should_remesh(hb) or len(hb.flagged) >= 3


# --- gradient compression ----------------------------------------------------


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = GC.quantize_int8(x)
    err = jnp.abs(GC.dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) / 2 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """With error feedback, the accumulated compressed sum converges to the
    accumulated true sum (residual stays bounded)."""
    g = jax.random.normal(jax.random.PRNGKey(1), (256,)) * 1e-3
    r = jnp.zeros(256)
    acc = jnp.zeros(256)
    for _ in range(50):
        q, s, r = GC.compress_residual(g, r)
        acc = acc + GC.dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(50 * g), atol=2 * float(s))


def test_psum_compressed_single_device():
    """shard_map psum of the compressed gradient == plain mean on 1 device."""
    from jax.sharding import Mesh, PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("dp",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (64,))}
    r = GC.init_residuals(g)

    def f(g, r):
        return GC.psum_compressed(g, r, "dp")

    out, r2 = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))(g, r)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]), atol=2e-2)


def test_trainer_loss_decreases():
    from repro.configs import reduced
    from repro.data.pipeline import pipeline_for
    from repro.models.registry import Model, get_config
    from repro.train.trainer import TrainLoop, TrainLoopConfig
    cfg = reduced(get_config("qwen3-0.6b"))
    model = Model(cfg)
    with tempfile.TemporaryDirectory() as d:
        loop = TrainLoop(model, OptimizerConfig(lr=3e-3, warmup_steps=3, total_steps=30),
                         TrainLoopConfig(total_steps=30, log_every=30, ckpt_every=30,
                                         ckpt_dir=d),
                         pipeline_for(cfg, shape_batch=4, seq_len=64))
        loop.run(resume=False)
        # compare first/last logged loss
        losses = [l for (_, l, _) in loop.history]
        assert losses[-1] < 5.56  # below random-init CE (ln 256 = 5.545 + margin)


def test_microbatch_accumulation_matches_full_batch():
    from repro.configs import reduced, smoke_batch
    from repro.models.registry import Model, get_config
    from repro.train.trainer import make_train_step
    cfg = reduced(get_config("qwen3-0.6b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = smoke_batch(cfg, batch=4, seq=32)
    ocfg = OptimizerConfig(lr=1e-3)
    s1 = make_train_step(model, ocfg, microbatches=1, donate=False)
    s2 = make_train_step(model, ocfg, microbatches=2, donate=False)
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    # losses equal; params close (grad mean over microbatches == full grad)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-5
