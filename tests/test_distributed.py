"""Distributed SpMV: partitioners + legacy primitives in-process, the plan
layer's variant/format/partitioner equivalence matrix, and real 4-/8-device
mesh assertions (in-process when REPRO_FORCE_DEVICES grants the devices,
via the subprocess harness otherwise — never silently reduced to 1 device).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed as D
from repro.core import distributed_plan as DP
from repro.core import spmv as S
from repro.core.matrices import holstein_hubbard_surrogate, power_law_rows


def _rel_err(y, y_ref):
    return float(np.max(np.abs(np.asarray(y) - y_ref)) / max(1e-9, np.max(np.abs(y_ref))))


# --- partitioners -----------------------------------------------------------


def test_nnz_balance_beats_row_balance():
    m = power_law_rows(2000, 2000, mean_nnz=8, seed=0, alpha=2.5)
    rows = D.partition_imbalance(m, D.row_balanced_partition(m.n_rows, 8))
    nnz = D.partition_imbalance(m, D.nnz_balanced_partition(m, 8))
    assert nnz <= rows * 1.001
    assert nnz < 1.2  # near-perfect work balance
    # on the paper's matrix too
    hh = holstein_hubbard_surrogate(1500, seed=0)
    assert (D.partition_imbalance(hh, D.nnz_balanced_partition(hh, 8))
            <= D.partition_imbalance(hh, D.row_balanced_partition(hh.n_rows, 8)))


def test_partition_bounds_cover_all_rows(hh_small):
    for parts in (1, 3, 8):
        b = D.nnz_balanced_partition(hh_small, parts)
        assert b[0] == 0 and b[-1] == hh_small.n_rows
        assert (np.diff(b) >= 0).all()


# --- legacy uniform-ELL primitives (paper-fidelity baseline) ----------------


def test_row_blocks_reconstruct(hh_small):
    blocks = D.build_row_blocks(hh_small, parts=4)
    # scattering every block entry back must reproduce the dense matrix rows
    d = np.zeros(hh_small.shape)
    for p in range(4):
        for i in range(blocks.col.shape[1]):
            r = blocks.row_map[p, i]
            if r >= hh_small.n_rows:
                continue
            for w in range(blocks.col.shape[2]):
                if blocks.val[p, i, w] != 0:
                    d[r, blocks.col[p, i, w]] += blocks.val[p, i, w]
    np.testing.assert_allclose(d, hh_small.to_dense(), atol=1e-5)


def test_single_device_shard_map_paths(hh_small):
    """Both legacy shard_map variants run on the session mesh and match."""
    mesh = D.make_mesh_1d()
    x = jnp.asarray(np.random.default_rng(0).standard_normal(hh_small.shape[1]).astype(np.float32))
    y_ref = np.asarray(S.csr_spmv(hh_small, x))
    for build, make in ((D.build_row_blocks, D.make_allgather_spmv),
                        (D.build_ring_blocks, D.make_ring_spmv)):
        blocks = build(hh_small, parts=len(jax.devices()))
        y = np.asarray(jax.jit(make(blocks, mesh))(x))
        np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=1e-4)


def test_traffic_models(hh_small):
    rb = D.build_row_blocks(hh_small, 4)
    ring = D.build_ring_blocks(hh_small, 4)
    t_ag = D.allgather_traffic_bytes(rb)
    t_ring = D.ring_traffic_bytes(ring)
    # the ring never holds more than one shard of x
    assert t_ring["per_chip_x"] < t_ag["per_chip_x"]


# --- plan layer: shard packing + format selection ---------------------------


def test_shard_slabs_reconstruct(hh_small):
    """Both packings of both layouts scatter back to the dense matrix."""
    dense = hh_small.to_dense()
    for pack in DP.SLAB_FORMATS:
        for local_cols in (False, True):
            blocks = DP.pack_shard_slabs(hh_small, 4, pack=pack, local_cols=local_cols)
            d = np.zeros(hh_small.shape)
            cs = blocks.col_shard
            for p in range(blocks.parts):
                for q in range(blocks.q_blocks):
                    base = q * cs if local_cols else 0
                    if pack == "ell":
                        for i in range(blocks.rows_pp):
                            r = blocks.row_map[p, i]
                            if r >= hh_small.n_rows:
                                continue
                            for w in range(blocks.col.shape[3]):
                                if blocks.val[p, q, i, w] != 0:
                                    d[r, base + blocks.col[p, q, i, w]] += blocks.val[p, q, i, w]
                    else:
                        for k in range(blocks.col.shape[2]):
                            i = blocks.rid[p, q, k]
                            if i >= blocks.rows_pp or blocks.val[p, q, k] == 0:
                                continue
                            r = blocks.row_map[p, i]
                            d[r, base + blocks.col[p, q, k]] += blocks.val[p, q, k]
            np.testing.assert_allclose(d, dense, atol=1e-5)


def test_shard_format_selection(hh_small):
    bounds = D.nnz_balanced_partition(hh_small, 4)
    reports = DP.plan_shard_formats(hh_small, bounds)
    assert len(reports) == 4
    assert sum(r.rows for r in reports) == hh_small.n_rows
    assert sum(r.nnz for r in reports) == hh_small.nnz
    for r in reports:
        assert r.format in DP.SLAB_FORMATS
        assert set(r.times) == set(DP.SLAB_FORMATS)
        assert r.local_nnz + r.remote_nnz == r.nnz
        assert r.predicted_time_s == min(r.times.values())
    chosen = DP.select_slab_format(reports)
    assert chosen in DP.SLAB_FORMATS
    # straggler rule: chosen format minimizes the max-over-shards time
    worst = {f: max(r.times[f] for r in reports) for f in DP.SLAB_FORMATS}
    assert worst[chosen] == min(worst.values())


# --- plan layer: equivalence on the session mesh ----------------------------


def test_distributed_plan_variants_match_reference(hh_small):
    """All three variants, model-chosen slab format, SpMV and SpMM."""
    n = hh_small.shape[1]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    X = jnp.asarray(rng.standard_normal((n, 8)).astype(np.float32))
    y_ref = np.asarray(S.csr_spmv(hh_small, x))
    Y_ref = hh_small.to_dense() @ np.asarray(X)
    for variant in DP.VARIANTS:
        plan = DP.compile_distributed_spmv_plan(hh_small, variant=variant)
        assert plan.parts == len(jax.devices())
        assert plan.imbalance >= 1.0
        np.testing.assert_allclose(np.asarray(plan(x)), y_ref, rtol=2e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(plan.spmm(X)), Y_ref, rtol=2e-4, atol=1e-4)


def test_distributed_plan_forced_slab_formats(hh_small):
    n = hh_small.shape[1]
    x = jnp.asarray(np.random.default_rng(1).standard_normal(n).astype(np.float32))
    y_ref = np.asarray(S.csr_spmv(hh_small, x))
    for slab in DP.SLAB_FORMATS:
        for balance in ("nnz", "rows"):
            plan = DP.compile_distributed_spmv_plan(
                hh_small, variant="overlap", slab_format=slab, balance=balance)
            assert plan.slab_format == slab
            np.testing.assert_allclose(np.asarray(plan(x)), y_ref, rtol=2e-4, atol=1e-4)


def test_distributed_plan_rejects_bad_shapes(hh_small):
    plan = DP.compile_distributed_spmv_plan(hh_small, variant="allgather")
    with pytest.raises(ValueError):
        plan(jnp.zeros(hh_small.shape[1] + 1, jnp.float32))
    with pytest.raises(ValueError):
        plan.spmm(jnp.zeros((hh_small.shape[1] + 1, 2), jnp.float32))
    with pytest.raises(ValueError):
        DP.compile_distributed_spmv_plan(hh_small, variant="nope")


def test_distributed_plan_report_and_traffic(hh_small):
    ag = DP.compile_distributed_spmv_plan(hh_small, variant="allgather")
    ov = DP.compile_distributed_spmv_plan(hh_small, variant="overlap")
    for plan in (ag, ov):
        r = plan.report
        assert r.format == f"dist-{plan.slab_format}"
        assert r.kernel == plan.variant
        assert r.nnz == hh_small.nnz and r.predicted_gflops > 0
        assert 0.0 <= plan.local_fraction <= 1.0
    # ring/overlap hold one x shard; allgather holds the full gathered copy
    assert ov.traffic["per_chip_x"] <= ag.traffic["per_chip_x"]


# --- plan layer: caching regressions (mirrors test_plan's row-id cache) -----


def test_distributed_plan_memoized_and_packs_once():
    """Compile is idempotent and each shard is packed exactly once per key:
    recompiling and re-executing never re-runs host preprocessing."""
    m = holstein_hubbard_surrogate(500, seed=9)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(500).astype(np.float32))
    parts = len(jax.devices())
    before = DP.pack_stats()
    p1 = DP.compile_distributed_spmv_plan(m, variant="overlap")
    for _ in range(3):
        p1(x)
        assert DP.compile_distributed_spmv_plan(m, variant="overlap") is p1
    after = DP.pack_stats()
    assert after["shard_packs"] - before["shard_packs"] == parts
    assert after["format_selections"] - before["format_selections"] == 1
    # a different layout compiles (and packs) separately ...
    p2 = DP.compile_distributed_spmv_plan(m, variant="allgather")
    assert p2 is not p1
    assert DP.pack_stats()["shard_packs"] - after["shard_packs"] == parts
    # ... but ring reuses overlap's packing outright (identical layout)
    before_ring = DP.pack_stats()
    p3 = DP.compile_distributed_spmv_plan(m, variant="ring")
    assert p3 is not p1 and p3.blocks is p1.blocks
    assert DP.pack_stats()["shard_packs"] == before_ring["shard_packs"]


# --- consumers ---------------------------------------------------------------


def test_eigensolver_with_distributed_plan(hh_small):
    from repro.core.eigensolver import ground_state_energy, lanczos

    ev0 = float(np.linalg.eigvalsh(hh_small.to_dense())[0])
    plan = DP.compile_distributed_spmv_plan(hh_small, variant="overlap")
    e_dist = ground_state_energy(plan, hh_small.shape[0], m=80)
    assert e_dist == pytest.approx(ev0, abs=5e-3)
    # mesh kwarg compiles the container into a distributed plan internally
    r = lanczos(hh_small, hh_small.shape[0], m=80, mesh=D.make_mesh_1d())
    assert float(r.eigenvalues[0]) == pytest.approx(ev0, abs=5e-3)


def test_server_register_distributed(hh_small):
    from repro.serve.engine import SparseOperatorServer

    srv = SparseOperatorServer()
    rep = srv.register_distributed("hh", hh_small, variant="overlap")
    assert rep.kernel == "overlap"
    x = jnp.asarray(np.random.default_rng(3).standard_normal(hh_small.shape[1]).astype(np.float32))
    np.testing.assert_allclose(np.asarray(srv.spmv("hh", x)),
                               np.asarray(S.spmv(hh_small, x)), rtol=2e-4, atol=1e-4)
    X = jnp.asarray(np.random.default_rng(4).standard_normal((hh_small.shape[1], 3)).astype(np.float32))
    assert np.asarray(srv.spmm("hh", X)).shape == (hh_small.shape[0], 3)
    st = srv.stats()["hh"]
    assert st["calls"] == 4
    assert st["variant"] == "overlap" and st["parts"] == len(jax.devices())
    assert st["imbalance"] >= 1.0 and 0.0 <= st["local_fraction"] <= 1.0


# --- real multi-device meshes: in-process when the session has them ---------

_EQUIV_WORKER = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core import formats as F
from repro.core.distributed_plan import VARIANTS, compile_distributed_spmv_plan
from repro.core.matrices import holstein_hubbard_surrogate

n = 1200
m = holstein_hubbard_surrogate(n, seed=2)
d = m.to_dense()
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
X = jnp.asarray(rng.standard_normal((n, 8)).astype(np.float32))
y_ref = d @ np.asarray(x)
Y_ref = d @ np.asarray(X)
errs = {"devices": len(jax.devices())}
for variant in VARIANTS:
    for balance in ("nnz", "rows"):
        for slab in ("ell", "sell"):
            p = compile_distributed_spmv_plan(m, variant=variant,
                                              balance=balance, slab_format=slab)
            e1 = float(np.max(np.abs(np.asarray(p(x)) - y_ref)) / np.max(np.abs(y_ref)))
            e8 = float(np.max(np.abs(np.asarray(p.spmm(X)) - Y_ref)) / np.max(np.abs(Y_ref)))
            errs[f"{variant}/{balance}/{slab}/nvec1"] = e1
            errs[f"{variant}/{balance}/{slab}/nvec8"] = e8
sell_in = F.SELL.from_csr(m, C=8)
p = compile_distributed_spmv_plan(sell_in, variant="overlap")
errs["overlap/sell-container"] = float(
    np.max(np.abs(np.asarray(p(x)) - y_ref)) / np.max(np.abs(y_ref)))
print(json.dumps(errs))
"""


@pytest.mark.slow
@pytest.mark.parametrize("n_devices", [4, 8])
def test_mesh_equivalence_matrix(emulated_devices_run, n_devices):
    """variants x partitioners x slab formats x nvec on a real emulated mesh
    (fresh subprocess, so it runs even from a 1-device session)."""
    errs = emulated_devices_run(n_devices, _EQUIV_WORKER)
    assert errs.pop("devices") == n_devices
    bad = {k: v for k, v in errs.items() if v >= 2e-4}
    assert not bad, f"fp32 equivalence failures on {n_devices} devices: {bad}"


@pytest.mark.multi_device
def test_multi_device_in_process_equivalence(hh_small):
    """When the session itself has >= 4 devices (REPRO_FORCE_DEVICES / CI
    distributed job), assert on real sub-meshes without a subprocess."""
    n = hh_small.shape[1]
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    X = jnp.asarray(rng.standard_normal((n, 8)).astype(np.float32))
    y_ref = np.asarray(S.csr_spmv(hh_small, x))
    Y_ref = hh_small.to_dense() @ np.asarray(X)
    sizes = [d for d in (4, 8) if d <= len(jax.devices())]
    for nd in sizes:
        mesh = D.make_mesh_1d(n_devices=nd)
        for variant in DP.VARIANTS:
            plan = DP.compile_distributed_spmv_plan(hh_small, mesh, variant=variant)
            assert plan.parts == nd
            np.testing.assert_allclose(np.asarray(plan(x)), y_ref, rtol=2e-4, atol=1e-4)
            np.testing.assert_allclose(np.asarray(plan.spmm(X)), Y_ref, rtol=2e-4, atol=1e-4)


@pytest.mark.multi_device
def test_multi_device_nnz_balance_helps(hh_small):
    """On a real mesh the nnz-balanced cut's stored-work imbalance must not
    exceed the row-balanced cut's (the paper's load-balance claim)."""
    mesh = D.make_mesh_1d(n_devices=min(8, len(jax.devices())))
    imb = {}
    for balance in ("nnz", "rows"):
        plan = DP.compile_distributed_spmv_plan(hh_small, mesh, variant="ring",
                                                balance=balance)
        imb[balance] = plan.imbalance
    assert imb["nnz"] <= imb["rows"] * 1.001


@pytest.mark.slow
def test_8device_equivalence_subprocess():
    """Run the module selftest under 8 forced host devices."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    env.pop("REPRO_FORCE_DEVICES", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.core.distributed", "2000"],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SELFTEST PASS" in out.stdout
