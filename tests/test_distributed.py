"""Distributed SpMV: partitioners in-process, 8-device equivalence via
subprocess (device count must be forced before jax init)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed as D
from repro.core import spmv as S
from repro.core.matrices import holstein_hubbard_surrogate, power_law_rows


def test_nnz_balance_beats_row_balance():
    m = power_law_rows(2000, 2000, mean_nnz=8, seed=0, alpha=2.5)
    rows = D.partition_imbalance(m, D.row_balanced_partition(m.n_rows, 8))
    nnz = D.partition_imbalance(m, D.nnz_balanced_partition(m, 8))
    assert nnz <= rows * 1.001
    assert nnz < 1.2  # near-perfect work balance
    # on the paper's matrix too
    hh = holstein_hubbard_surrogate(1500, seed=0)
    assert (D.partition_imbalance(hh, D.nnz_balanced_partition(hh, 8))
            <= D.partition_imbalance(hh, D.row_balanced_partition(hh.n_rows, 8)))


def test_partition_bounds_cover_all_rows(hh_small):
    for parts in (1, 3, 8):
        b = D.nnz_balanced_partition(hh_small, parts)
        assert b[0] == 0 and b[-1] == hh_small.n_rows
        assert (np.diff(b) >= 0).all()


def test_row_blocks_reconstruct(hh_small):
    blocks = D.build_row_blocks(hh_small, parts=4)
    # scattering every block entry back must reproduce the dense matrix rows
    d = np.zeros(hh_small.shape)
    for p in range(4):
        for i in range(blocks.col.shape[1]):
            r = blocks.row_map[p, i]
            if r >= hh_small.n_rows:
                continue
            for w in range(blocks.col.shape[2]):
                if blocks.val[p, i, w] != 0:
                    d[r, blocks.col[p, i, w]] += blocks.val[p, i, w]
    np.testing.assert_allclose(d, hh_small.to_dense(), atol=1e-5)


def test_single_device_shard_map_paths(hh_small):
    """Both shard_map variants run (1-device mesh) and match the reference."""
    mesh = D.make_mesh_1d()
    x = jnp.asarray(np.random.default_rng(0).standard_normal(hh_small.shape[1]).astype(np.float32))
    y_ref = np.asarray(S.csr_spmv(hh_small, x))
    for build, make in ((D.build_row_blocks, D.make_allgather_spmv),
                        (D.build_ring_blocks, D.make_ring_spmv)):
        blocks = build(hh_small, parts=len(jax.devices()))
        y = np.asarray(jax.jit(make(blocks, mesh))(x))
        np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=1e-4)


def test_traffic_models(hh_small):
    rb = D.build_row_blocks(hh_small, 4)
    ring = D.build_ring_blocks(hh_small, 4)
    t_ag = D.allgather_traffic_bytes(rb)
    t_ring = D.ring_traffic_bytes(ring)
    # the ring never holds more than one shard of x
    assert t_ring["per_chip_x"] < t_ag["per_chip_x"]


@pytest.mark.slow
def test_8device_equivalence_subprocess():
    """Run the module selftest under 8 forced host devices."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.core.distributed", "2000"],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SELFTEST PASS" in out.stdout
