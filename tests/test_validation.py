"""Input-validation layer: matrix/vector checks, malformed-file provenance,
per-dtype tree finiteness, and Lanczos breakdown detection."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.eigensolver import LanczosBreakdown, lanczos  # noqa: E402
from repro.core.formats import COO, CSR  # noqa: E402
from repro.core.io import read_mtx, write_mtx  # noqa: E402
from repro.core.plan import SpMVPlan  # noqa: E402
from repro.core.validate import (  # noqa: E402
    MatrixFormatError,
    MatrixValidationError,
    VectorValidationError,
    dtype_overflow_count,
    inspect_matrix,
    validate_matrix,
    validate_vector,
)
from repro.utils.tree import tree_any_nan, tree_any_nonfinite  # noqa: E402

MALFORMED = __import__("pathlib").Path(__file__).parent / "fixtures" / "malformed"


def _clean_csr(n=8):
    rng = np.random.default_rng(3)
    dense = (rng.random((n, n)) < 0.4) * rng.standard_normal((n, n))
    rows, cols = np.nonzero(dense)
    return CSR.from_coo(COO(rows.astype(np.int32), cols.astype(np.int32),
                            dense[rows, cols].astype(np.float32), (n, n)))


# ---------------------------------------------------------------------------
# matrix validation policies
# ---------------------------------------------------------------------------


class TestValidateMatrix:
    def test_clean_matrix_passes_strict(self):
        m = _clean_csr()
        assert validate_matrix(m, policy="strict") is m

    def test_off_returns_untouched(self):
        bad = CSR(np.array([0, 1], np.int32), np.array([99], np.int32),
                  np.array([1.0], np.float32), (1, 4))
        assert validate_matrix(bad, policy="off") is bad

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError, match="unknown validation policy"):
            validate_matrix(_clean_csr(), policy="lenient")

    def test_oob_index_strict(self):
        bad = CSR(np.array([0, 2], np.int32), np.array([0, 99], np.int32),
                  np.array([1.0, 2.0], np.float32), (1, 4))
        with pytest.raises(MatrixValidationError, match="out of range"):
            validate_matrix(bad)

    def test_oob_index_repaired(self):
        bad = CSR(np.array([0, 2], np.int32), np.array([0, 99], np.int32),
                  np.array([1.0, 2.0], np.float32), (1, 4))
        fixed = validate_matrix(bad, policy="repair")
        assert fixed.nnz == 1
        assert "dropped 1 out-of-range entries" in fixed._repairs

    def test_duplicates_merged_by_repair(self):
        dup = COO(np.array([0, 0, 1], np.int32), np.array([1, 1, 0], np.int32),
                  np.array([2.0, 3.0, 1.0], np.float32), (2, 2))
        with pytest.raises(MatrixValidationError, match="duplicate"):
            validate_matrix(dup)
        fixed = validate_matrix(dup, policy="repair")
        assert fixed.nnz == 2
        dense = np.zeros((2, 2), np.float32)
        dense[np.asarray(fixed.rows), np.asarray(fixed.cols)] = np.asarray(fixed.vals)
        assert dense[0, 1] == 5.0  # duplicate values summed

    def test_nonfinite_values_strict_and_repair(self):
        bad = COO(np.array([0, 1], np.int32), np.array([0, 1], np.int32),
                  np.array([np.nan, 2.0], np.float32), (2, 2))
        with pytest.raises(MatrixValidationError, match="non-finite"):
            validate_matrix(bad)
        fixed = validate_matrix(bad, policy="repair")
        assert np.isfinite(np.asarray(fixed.vals)).all()

    def test_unsorted_csr_detected(self):
        m = CSR(np.array([0, 2], np.int32), np.array([3, 1], np.int32),
                np.array([1.0, 2.0], np.float32), (1, 4))
        rep = inspect_matrix(m)
        assert any("not sorted" in p for p in rep.problems)
        fixed = validate_matrix(m, policy="repair")
        assert np.all(np.diff(np.asarray(fixed.col_idx)) > 0)

    def test_broken_row_ptr(self):
        m = CSR(np.array([0, 2, 1], np.int32), np.array([0, 1], np.int32),
                np.array([1.0, 2.0], np.float32), (2, 2))
        with pytest.raises(MatrixValidationError, match="monotone"):
            validate_matrix(m)

    def test_dtype_overflow_counted(self):
        vals = np.array([1.0, 1e300, -4e38], np.float64)
        assert dtype_overflow_count(vals, np.float32) == 2
        assert dtype_overflow_count(vals, np.float64) == 0
        big = COO(np.arange(3, dtype=np.int32), np.arange(3, dtype=np.int32),
                  vals, (3, 3))
        with pytest.raises(MatrixValidationError, match="overflow"):
            validate_matrix(big, value_dtype=np.float32)

    def test_plan_compile_validates(self):
        bad = CSR(np.array([0, 1], np.int32), np.array([99], np.int32),
                  np.array([1.0], np.float32), (1, 4))
        with pytest.raises(MatrixValidationError):
            SpMVPlan.compile(bad, validate="strict")
        plan = SpMVPlan.compile(bad, validate="repair")
        assert plan.report.nnz == 0  # the one bad entry was dropped


class TestValidateVector:
    def test_bad_shape_raises_under_every_policy(self):
        for policy in ("strict", "repair", "off"):
            with pytest.raises(VectorValidationError, match="expected"):
                validate_vector(jnp.zeros(3), 4, policy=policy)

    def test_strict_rejects_nan(self):
        x = jnp.asarray([1.0, np.nan, 3.0], jnp.float32)
        with pytest.raises(VectorValidationError, match="non-finite"):
            validate_vector(x, 3, policy="strict")

    def test_repair_zeroes_nonfinite(self):
        x = jnp.asarray([1.0, np.nan, np.inf], jnp.float32)
        y = validate_vector(x, 3, policy="repair")
        assert np.array_equal(np.asarray(y), [1.0, 0.0, 0.0])

    def test_off_passes_anything_finite_shaped(self):
        x = jnp.asarray([np.nan], jnp.float32)
        assert validate_vector(x, 1, policy="off") is x


# ---------------------------------------------------------------------------
# malformed MatrixMarket files: error class + line provenance
# ---------------------------------------------------------------------------


class TestMalformedFiles:
    @pytest.mark.parametrize("fixture, line, match", [
        ("bad_banner.mtx", 1, "not a MatrixMarket file"),
        ("bad_size_line.mtx", 3, "bad size line"),
        ("nonnumeric_entry.mtx", 4, "not numeric"),
        ("oob_entry.mtx", 5, "out of range"),
        ("count_mismatch.mtx", 2, "declares 5 entries"),
        ("too_few_fields.mtx", 4, "fields"),
    ])
    def test_line_provenance(self, fixture, line, match):
        path = MALFORMED / fixture
        with pytest.raises(MatrixFormatError, match=match) as ei:
            read_mtx(path)
        assert ei.value.line == line
        assert str(path) in str(ei.value)
        assert f":{line}:" in str(ei.value)

    def test_format_error_is_value_error(self):
        with pytest.raises(ValueError):
            read_mtx(MALFORMED / "bad_banner.mtx")

    def test_nan_value_policy(self):
        path = MALFORMED / "nan_value.mtx"
        with pytest.raises(MatrixValidationError, match="non-finite"):
            read_mtx(path)
        coo = read_mtx(path, validate="off")
        assert np.isnan(np.asarray(coo.vals)).any()
        fixed = read_mtx(path, validate="repair")
        assert np.isfinite(np.asarray(fixed.vals)).all()
        assert fixed._source == str(path)  # provenance survives the repair

    def test_duplicate_entries_policy(self):
        path = MALFORMED / "duplicate_entries.mtx"
        with pytest.raises(MatrixValidationError, match="duplicate"):
            read_mtx(path)
        fixed = read_mtx(path, validate="repair")
        assert fixed.nnz == 3

    def test_clean_roundtrip_still_works(self, tmp_path):
        m = _clean_csr()
        p = write_mtx(tmp_path / "ok.mtx", m.to_coo())
        coo = read_mtx(p)
        assert coo.nnz == m.nnz


# ---------------------------------------------------------------------------
# per-dtype tree finiteness (the f32-upcast regression)
# ---------------------------------------------------------------------------


class TestTreeFiniteness:
    def test_nan_detected_in_native_dtype(self):
        for dt in (jnp.float16, jnp.bfloat16, jnp.float32):
            tree = {"w": jnp.asarray([1.0, np.nan], dt)}
            assert tree_any_nan(tree)
            assert tree_any_nonfinite(tree)

    def test_inf_detected_without_upcast(self):
        # f16 Inf: the old ``.astype(jnp.float32)`` path kept this finite
        # under isnan; tree_any_nonfinite must flag it in the leaf's dtype
        tree = {"w": jnp.asarray([1.0, np.inf], jnp.float16)}
        assert not tree_any_nan(tree)
        assert tree_any_nonfinite(tree)

    def test_f16_overflow_scale_is_nonfinite(self):
        # a value representable in f32 but not f16 can only exist in the
        # tree as f16 Inf — the check must see it without any cast
        x = np.float16(70000.0)  # overflows f16 -> inf at construction
        tree = {"w": jnp.asarray([x], jnp.float16)}
        assert tree_any_nonfinite(tree)

    def test_clean_and_nonfloat_trees(self):
        tree = {"a": jnp.ones(3, jnp.float16), "b": jnp.arange(3)}
        assert not tree_any_nan(tree)
        assert not tree_any_nonfinite(tree)
        assert not tree_any_nonfinite({"ints": jnp.arange(4)})


# ---------------------------------------------------------------------------
# Lanczos breakdown detection + restart
# ---------------------------------------------------------------------------


class TestLanczosBreakdown:
    def _matrix(self, n=32):
        rng = np.random.default_rng(5)
        dense = rng.standard_normal((n, n)).astype(np.float32)
        dense = (dense + dense.T) / 2
        return dense

    def test_nan_operator_raises_structured(self):
        dense = self._matrix()
        calls = {"n": 0}

        def apply_A(v):
            calls["n"] += 1
            y = jnp.asarray(dense) @ v
            return y.at[0].set(jnp.nan)

        with pytest.raises(LanczosBreakdown) as ei:
            lanczos(apply_A, dense.shape[0], m=8, dtype=jnp.float32)
        assert ei.value.iteration == 0
        assert not np.isfinite(ei.value.alpha) or not np.isfinite(ei.value.beta)

    def test_transient_fault_restart_recovers(self):
        dense = self._matrix()
        calls = {"n": 0}

        def apply_A(v):
            calls["n"] += 1
            y = jnp.asarray(dense) @ v
            if calls["n"] == 1:  # only the very first SpMV is poisoned
                y = y.at[0].set(jnp.nan)
            return y

        r = lanczos(apply_A, dense.shape[0], m=32, dtype=jnp.float32,
                    on_breakdown="restart")
        ref = np.linalg.eigvalsh(dense)
        assert abs(r.eigenvalues[0] - ref[0]) < 1e-2
        assert r.n_spmv == calls["n"]  # failed attempt's SpMVs are counted

    def test_persistent_fault_exhausts_restarts(self):
        def apply_A(v):
            return jnp.full_like(v, jnp.nan)

        with pytest.raises(LanczosBreakdown):
            lanczos(apply_A, 16, m=4, dtype=jnp.float32,
                    on_breakdown="restart", max_restarts=2)

    def test_unknown_on_breakdown_rejected(self):
        with pytest.raises(ValueError, match="on_breakdown"):
            lanczos(lambda v: v, 4, m=2, on_breakdown="ignore")

    def test_clean_solve_unchanged(self):
        dense = self._matrix()
        r = lanczos(jnp.asarray(dense).__matmul__, dense.shape[0], m=32,
                    dtype=jnp.float32)
        ref = np.linalg.eigvalsh(dense)
        assert abs(r.eigenvalues[0] - ref[0]) < 1e-2
