"""Storage-format containers: conversions, roundtrips, invariants.

Hypothesis property sweeps live in test_property.py (optional test extra).
"""
import numpy as np
import pytest

from repro.core import formats as F

FORMATS = [("csr", {}), ("ell", {}), ("jds", {}), ("sell", dict(C=8)),
           ("sell", dict(C=8, sigma=32)), ("sell", dict(C=16, sort_cols=True)),
           ("hybrid", {})]


@pytest.mark.parametrize("fmt,kw", FORMATS)
def test_roundtrip_dense(hh_small, fmt, kw):
    d = hh_small.to_dense()
    obj = F.convert(hh_small, fmt, **kw)
    np.testing.assert_allclose(obj.to_dense(), d, atol=1e-5)


def test_csr_coo_roundtrip(hh_small):
    coo = hh_small.to_coo()
    back = F.CSR.from_coo(coo)
    np.testing.assert_array_equal(back.row_ptr, hh_small.row_ptr)
    np.testing.assert_array_equal(back.col_idx, hh_small.col_idx)


def test_bsr_roundtrip():
    from repro.core.matrices import block_sparse_dense
    d = block_sparse_dense(64, 256, (8, 128), 0.5, seed=0)
    bsr = F.BSR.from_dense(d, (8, 128))
    np.testing.assert_allclose(bsr.to_dense(), d, atol=0)
    assert 0.0 < bsr.density() <= 1.0


def test_jds_permutation_sorted(hh_small):
    jds = F.JDS.from_csr(hh_small)
    lens = hh_small.row_lengths()[np.asarray(jds.perm)]
    assert (np.diff(lens) <= 0).all(), "JDS rows must be sorted by decreasing length"
    assert jds.n_diags == int(hh_small.row_lengths().max())
    assert jds.nnz == hh_small.nnz


def test_sell_chunk_geometry(hh_small):
    sell = F.SELL.from_csr(hh_small, C=8, sigma=64)
    assert sell.n_chunks == -(-hh_small.n_rows // 8)
    cp = np.asarray(sell.chunk_ptr)
    cw = np.asarray(sell.chunk_width)
    np.testing.assert_array_equal(np.diff(cp), cw.astype(np.int64) * 8)


def test_sell_sigma_full_matches_jds_order(hh_small):
    # explicit sigma = n: full sort (sigma=None now means DEFAULT_SELL_SIGMA)
    sell = F.SELL.from_csr(hh_small, C=8, sigma=hh_small.n_rows)
    jds = F.JDS.from_csr(hh_small)
    n = hh_small.n_rows
    np.testing.assert_array_equal(np.asarray(sell.perm)[:n], np.asarray(jds.perm))


def test_split_dia_captures_diagonals(hh_small):
    hyb = F.split_dia(hh_small, min_occupancy=0.5, max_diags=16)
    assert len(np.asarray(hyb.dia.offsets)) > 0
    frac = hyb.dia.nnz / hh_small.nnz
    assert 0.3 < frac < 0.95  # the dense diagonals carry the bulk


def test_matrix_stats(hh_small):
    st_ = F.matrix_stats(hh_small)
    assert st_["nnz"] == hh_small.nnz
    assert 5 < st_["nnz_per_row_mean"] < 25
    assert 0.0 <= st_["frac_backward_jumps"] <= 1.0
    assert st_["frac_nnz_top12_diags"] > 0.3
