"""Reference SpMV per format vs the dense oracle.

Hypothesis property sweeps live in test_property.py (optional test extra).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats as F
from repro.core import spmv as S
from repro.core.matrices import block_sparse_dense, laplacian_2d

FORMATS = [("csr", {}), ("ell", {}), ("jds", {}), ("sell", dict(C=8)),
           ("sell", dict(C=16, sigma=32, sort_cols=True)), ("hybrid", {})]


def _check(m, fmt, kw, dtype=np.float32, rtol=2e-5):
    d = m.to_dense().astype(np.float64)
    x = np.random.default_rng(3).standard_normal(m.shape[1]).astype(dtype)
    y_ref = d @ x.astype(np.float64)
    obj = F.convert(m, fmt, **kw)
    y = np.asarray(S.spmv(obj, jnp.asarray(x)), np.float64)
    scale = max(1e-9, np.abs(y_ref).max())
    assert np.abs(y - y_ref).max() / scale < rtol, fmt


@pytest.mark.parametrize("fmt,kw", FORMATS)
def test_formats_vs_dense(hh_small, fmt, kw):
    _check(hh_small, fmt, kw)


@pytest.mark.parametrize("fmt,kw", FORMATS)
def test_laplacian(fmt, kw):
    _check(laplacian_2d(16, 12, dtype=np.float32), fmt, kw)


def test_bsr_spmv_spmm():
    d = block_sparse_dense(64, 256, (8, 128), 0.4, seed=1)
    m = F.BSR.from_dense(d, (8, 128))
    x = np.random.default_rng(0).standard_normal(256).astype(np.float32)
    y = np.asarray(S.bsr_spmv(m, jnp.asarray(x)))
    np.testing.assert_allclose(y, d @ x, rtol=2e-4, atol=1e-4)
    X = np.random.default_rng(1).standard_normal((256, 16)).astype(np.float32)
    Y = np.asarray(S.bsr_spmm(m, jnp.asarray(X)))
    np.testing.assert_allclose(Y, d @ X, rtol=2e-4, atol=1e-4)


def test_make_spmv_jitted(hh_small):
    f = S.make_spmv(F.convert(hh_small, "sell", C=8))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(hh_small.shape[1]).astype(np.float32))
    y1 = f(x)
    y2 = f(x * 2)
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1), rtol=1e-5)


def test_flops_accounting(hh_small):
    assert S.flops_of(hh_small) == 2 * hh_small.nnz


def test_row_ids_cached_no_recompute():
    """csr_row_ids / bsr_block_row_ids build once per container, ever."""
    from repro.core.matrices import holstein_hubbard_surrogate
    m = holstein_hubbard_surrogate(300, seed=11)
    before = S.precompute_stats()
    ids1 = S.csr_row_ids(m)
    x = jnp.asarray(np.ones(300, np.float32))
    f = S.make_spmv(m)
    for _ in range(3):
        f(x)
        S.spmv(m, x)
    ids2 = S.csr_row_ids(m)
    assert ids1 is ids2
    assert S.precompute_stats()["csr_row_ids"] - before["csr_row_ids"] == 1

    d = block_sparse_dense(32, 256, (8, 128), 0.5, seed=4)
    mb = F.BSR.from_dense(d, (8, 128))
    before = S.precompute_stats()
    xb = jnp.asarray(np.ones(256, np.float32))
    for _ in range(3):
        S.bsr_spmv(mb, xb)
    assert S.precompute_stats()["bsr_block_row_ids"] - before["bsr_block_row_ids"] == 1


def test_naive_matches_vectorized(hh_small):
    """The legacy formulations (benchmark baseline) agree with the new
    vectorized dispatch for every format that has both."""
    x = jnp.asarray(np.random.default_rng(5).standard_normal(hh_small.shape[1]).astype(np.float32))
    for fmt, kw in [("csr", {}), ("jds", {}), ("sell", dict(C=8)), ("hybrid", {})]:
        obj = F.convert(hh_small, fmt, **kw)
        np.testing.assert_allclose(np.asarray(S.naive_spmv(obj, x)),
                                   np.asarray(S.spmv(obj, x)), rtol=2e-5, atol=2e-5)


def test_empty_rows():
    # rows with zero entries must produce zeros, not garbage
    rows = np.array([0, 0, 3], np.int32)
    cols = np.array([1, 2, 0], np.int32)
    vals = np.array([1.0, 2.0, 3.0], np.float32)
    m = F.CSR.from_coo(F.COO(rows, cols, vals, (5, 4)))
    x = jnp.asarray(np.ones(4, np.float32))
    for fmt, kw in FORMATS:
        y = np.asarray(S.spmv(F.convert(m, fmt, **kw), x))
        np.testing.assert_allclose(y, m.to_dense() @ np.ones(4), atol=1e-6)
