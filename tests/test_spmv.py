"""Reference SpMV per format vs the dense oracle (+ hypothesis sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core import spmv as S
from repro.core.matrices import block_sparse_dense, laplacian_2d, random_sparse

FORMATS = [("csr", {}), ("ell", {}), ("jds", {}), ("sell", dict(C=8)),
           ("sell", dict(C=16, sigma=32, sort_cols=True)), ("hybrid", {})]


def _check(m, fmt, kw, dtype=np.float32, rtol=2e-5):
    d = m.to_dense().astype(np.float64)
    x = np.random.default_rng(3).standard_normal(m.shape[1]).astype(dtype)
    y_ref = d @ x.astype(np.float64)
    obj = F.convert(m, fmt, **kw)
    y = np.asarray(S.spmv(obj, jnp.asarray(x)), np.float64)
    scale = max(1e-9, np.abs(y_ref).max())
    assert np.abs(y - y_ref).max() / scale < rtol, fmt


@pytest.mark.parametrize("fmt,kw", FORMATS)
def test_formats_vs_dense(hh_small, fmt, kw):
    _check(hh_small, fmt, kw)


@pytest.mark.parametrize("fmt,kw", FORMATS)
def test_laplacian(fmt, kw):
    _check(laplacian_2d(16, 12, dtype=np.float32), fmt, kw)


def test_bsr_spmv_spmm():
    d = block_sparse_dense(64, 256, (8, 128), 0.4, seed=1)
    m = F.BSR.from_dense(d, (8, 128))
    x = np.random.default_rng(0).standard_normal(256).astype(np.float32)
    y = np.asarray(S.bsr_spmv(m, jnp.asarray(x)))
    np.testing.assert_allclose(y, d @ x, rtol=2e-4, atol=1e-4)
    X = np.random.default_rng(1).standard_normal((256, 16)).astype(np.float32)
    Y = np.asarray(S.bsr_spmm(m, jnp.asarray(X)))
    np.testing.assert_allclose(Y, d @ X, rtol=2e-4, atol=1e-4)


def test_make_spmv_jitted(hh_small):
    f = S.make_spmv(F.convert(hh_small, "sell", C=8))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(hh_small.shape[1]).astype(np.float32))
    y1 = f(x)
    y2 = f(x * 2)
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1), rtol=1e-5)


def test_flops_accounting(hh_small):
    assert S.flops_of(hh_small) == 2 * hh_small.nnz


def test_empty_rows():
    # rows with zero entries must produce zeros, not garbage
    rows = np.array([0, 0, 3], np.int32)
    cols = np.array([1, 2, 0], np.int32)
    vals = np.array([1.0, 2.0, 3.0], np.float32)
    m = F.CSR.from_coo(F.COO(rows, cols, vals, (5, 4)))
    x = jnp.asarray(np.ones(4, np.float32))
    for fmt, kw in FORMATS:
        y = np.asarray(S.spmv(F.convert(m, fmt, **kw), x))
        np.testing.assert_allclose(y, m.to_dense() @ np.ones(4), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(8, 48), nnz=st.integers(1, 8), seed=st.integers(0, 999))
def test_property_spmv_equivalence(n, nnz, seed):
    """All formats compute the same y for random matrices (the system's
    central invariant: storage scheme never changes the math)."""
    m = random_sparse(n, n, min(nnz, n), seed=seed)
    x = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
    ys = {}
    for fmt, kw in [("csr", {}), ("ell", {}), ("jds", {}), ("sell", dict(C=4))]:
        ys[fmt] = np.asarray(S.spmv(F.convert(m, fmt, **kw), jnp.asarray(x)))
    base = ys.pop("csr")
    for fmt, y in ys.items():
        np.testing.assert_allclose(y, base, rtol=2e-4, atol=2e-5)
