"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced, smoke_batch
from repro.models.registry import Model, get_config
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state

ALL_ARCHS = list(ARCHS)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = reduced(get_config(name))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, batch=2, seq=32)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), name
    assert float(loss) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_train_step(name):
    cfg = reduced(get_config(name))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = smoke_batch(cfg, batch=2, seq=32)

    @jax.jit
    def step(p, o, b):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        p2, o2, stats = adamw_update(OptimizerConfig(lr=1e-3), grads, o, p)
        return p2, o2, loss, stats

    p2, o2, loss, stats = step(params, opt, batch)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(stats["grad_norm"])) and float(stats["grad_norm"]) > 0
    # params must actually change
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).sum()), p2, params))
    assert delta > 0, name
    # no NaNs anywhere in the updated tree
    for leaf in jax.tree.leaves(p2):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), name


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_step_shapes(name):
    cfg = reduced(get_config(name))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    cache = model.init_cache(B, 48)
    batch = smoke_batch(cfg, batch=B, seq=16)
    pre = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache2 = jax.jit(model.prefill)(params, pre, cache)
    assert logits.shape == (B, cfg.vocab)
    if cfg.input_mode == "embeds" and cfg.family != "encdec":
        tok = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.d_model), jnp.bfloat16)
    else:
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, _ = jax.jit(model.decode_step)(params, cache2, tok, jnp.int32(16))
    assert logits2.shape == (B, cfg.vocab)
    assert bool(np.isfinite(np.asarray(logits2, np.float32)).all()), name
