"""tools/check_bench.py comparator: the CI perf-regression gate must catch
an injected >=25% regression and tolerate noise below the threshold."""
import copy
import json
import subprocess
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
import check_bench  # noqa: E402

REPO_ROOT = Path(__file__).resolve().parent.parent


@pytest.fixture()
def baseline():
    """A miniature BENCH-shaped artifact covering every gated section."""
    return {
        "backend": "cpu",
        "formats": {
            "csr": {"gflops_planned": 0.10, "t_planned_s": 1e-3,
                    "speedup_plan_vs_naive": 1.5},
            "sell": {"gflops_planned": 0.30, "t_planned_s": 4e-4},
        },
        "distributed": {"devices": 8, "variants": {
            "overlap": {"gflops": 0.20, "t_s": 2e-3},
            "ring": {"gflops": 0.18, "t_s": 2e-3},
        }},
        "serving": {"speedup_at_width8": 3.0,
                    "sequential": {"qps": 200.0, "t_query_s": 5e-3}},
        "corpus": {"matrices": {"banded": {"formats": {
            "dia": {"gflops": 0.5, "t_measured_s": 1e-4}}}}},
    }


def test_extract_metrics_keeps_only_higher_is_better(baseline):
    m = check_bench.extract_metrics(baseline)
    assert m["formats/csr/gflops_planned"] == 0.10
    assert m["serving/speedup_at_width8"] == 3.0
    assert m["corpus/matrices/banded/formats/dia/gflops"] == 0.5
    # timings and counters must never enter the gate
    assert not any(k.endswith(("t_planned_s", "t_s", "t_query_s",
                               "t_measured_s", "devices")) for k in m)


def test_identical_artifacts_pass(baseline):
    cmp = check_bench.compare(baseline, baseline, tolerance=0.25)
    assert cmp.ok and cmp.geomean_ratio == pytest.approx(1.0)
    assert cmp.n_shared == len(check_bench.extract_metrics(baseline))


def _scaled(payload, factor):
    out = copy.deepcopy(payload)

    def walk(d):
        for k, v in d.items():
            if isinstance(v, dict):
                walk(v)
            elif k in check_bench.HIGHER_BETTER_KEYS:
                d[k] = v * factor
    walk(out)
    return out


def test_injected_25pct_regression_fails(baseline):
    """The acceptance case: a synthetic fleet-wide >=25% drop must fail."""
    cmp = check_bench.compare(_scaled(baseline, 0.70), baseline, tolerance=0.25)
    assert not cmp.ok
    assert cmp.geomean_ratio == pytest.approx(0.70, rel=1e-6)
    assert len(cmp.regressions) == cmp.n_shared


def test_noise_below_tolerance_passes(baseline):
    cmp = check_bench.compare(_scaled(baseline, 0.85), baseline, tolerance=0.25)
    assert cmp.ok


def test_single_metric_drop_warns_but_passes(baseline):
    new = copy.deepcopy(baseline)
    new["formats"]["csr"]["gflops_planned"] = 0.02  # one 5x regression
    cmp = check_bench.compare(new, baseline, tolerance=0.25)
    assert "formats/csr/gflops_planned" in cmp.regressions
    assert cmp.ok  # geomean over the fleet absorbs one noisy metric


def test_disjoint_schemas_pass_vacuously(baseline):
    cmp = check_bench.compare({"totally": {"new": 1.0}}, baseline)
    assert cmp.ok and cmp.n_shared == 0


def test_improvements_pass(baseline):
    cmp = check_bench.compare(_scaled(baseline, 1.8), baseline, tolerance=0.25)
    assert cmp.ok and cmp.geomean_ratio > 1.7


def test_cli_exit_codes_and_summary(tmp_path, baseline):
    """End-to-end through the CLI, exactly as the CI step invokes it."""
    base_p = tmp_path / "base.json"
    base_p.write_text(json.dumps(baseline))
    bad_p = tmp_path / "bad.json"
    bad_p.write_text(json.dumps(_scaled(baseline, 0.6)))
    summary = tmp_path / "summary.md"

    ok = subprocess.run(
        [sys.executable, str(REPO_ROOT / "tools" / "check_bench.py"),
         "--new", str(base_p), "--baseline", str(base_p),
         "--summary-file", str(summary)],
        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    assert "perf gate OK" in summary.read_text()

    bad = subprocess.run(
        [sys.executable, str(REPO_ROOT / "tools" / "check_bench.py"),
         "--new", str(bad_p), "--baseline", str(base_p), "--tolerance", "0.25"],
        capture_output=True, text=True)
    assert bad.returncode == 1
    assert "REGRESSION" in bad.stdout


class TestBounds:
    """--bound 'path<=value': absolute invariants on the fresh artifact."""

    PAYLOAD = {"serving": {"guardrails": {"overhead_ratio": 1.02},
                           "speedup_at_width8": 3.0}}

    def test_lookup_path(self):
        assert check_bench.lookup_path(
            self.PAYLOAD, "serving/guardrails/overhead_ratio") == 1.02
        with pytest.raises(KeyError, match="not found"):
            check_bench.lookup_path(self.PAYLOAD, "serving/missing/x")
        with pytest.raises(TypeError, match="not numeric"):
            check_bench.lookup_path({"a": {"b": "str"}}, "a/b")

    def test_upper_and_lower_bounds(self):
        ok, _ = check_bench.check_bound(
            self.PAYLOAD, "serving/guardrails/overhead_ratio<=1.05")
        assert ok
        ok, line = check_bench.check_bound(
            self.PAYLOAD, "serving/guardrails/overhead_ratio<=1.01")
        assert not ok and "FAILED" in line
        ok, _ = check_bench.check_bound(
            self.PAYLOAD, "serving/speedup_at_width8>=2.0")
        assert ok
        ok, _ = check_bench.check_bound(
            self.PAYLOAD, "serving/speedup_at_width8>=5.0")
        assert not ok

    def test_missing_path_fails_not_skips(self):
        # an invariant that stopped being measured is itself a regression
        ok, line = check_bench.check_bound(self.PAYLOAD, "gone/metric<=1.0")
        assert not ok and "FAILED" in line

    def test_malformed_spec_raises(self):
        with pytest.raises(ValueError, match="expected"):
            check_bench.check_bound(self.PAYLOAD, "no-operator-here")
        with pytest.raises(ValueError, match="not a number"):
            check_bench.check_bound(self.PAYLOAD, "a/b<=abc")

    def test_cli_bound_gates_exit_code(self, tmp_path, baseline):
        new = copy.deepcopy(baseline)
        new["serving"]["guardrails"] = {"overhead_ratio": 1.10}
        new_p = tmp_path / "new.json"
        new_p.write_text(json.dumps(new))
        base_p = tmp_path / "base.json"
        base_p.write_text(json.dumps(baseline))

        def run(*bounds):
            cmd = [sys.executable, str(REPO_ROOT / "tools" / "check_bench.py"),
                   "--new", str(new_p), "--baseline", str(base_p)]
            for b in bounds:
                cmd += ["--bound", b]
            return subprocess.run(cmd, capture_output=True, text=True)

        # geomean passes (identical metrics) but the bound fails -> exit 1
        r = run("serving/guardrails/overhead_ratio<=1.05")
        assert r.returncode == 1 and "bound FAILED" in r.stdout
        # relaxed bound passes
        r = run("serving/guardrails/overhead_ratio<=1.20")
        assert r.returncode == 0 and "bound ok" in r.stdout


def test_committed_artifacts_are_gate_compatible():
    """The real committed trajectory must share metrics (the CI gate's
    comparison is not vacuous) and the PR3 artifact must pass against
    itself."""
    with open(REPO_ROOT / "BENCH_PR3.json") as fh:
        pr3 = json.load(fh)
    assert check_bench.compare(pr3, pr3).ok
    m = check_bench.extract_metrics(pr3)
    assert len(m) >= 10
