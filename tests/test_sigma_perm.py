"""SELL-C-sigma permutation properties + the PlanConfig compile API (PR9).

The tentpole contract: sigma-window row sorting is a *pack-time layout
choice* — ``plan(x)`` always returns rows in the original order, for every
sigma, every backend formulation (padded XLA views, flat segment-sum XLA,
the loop oracle), and every stored value dtype (per-chunk quantization
scales must follow the permutation).  Plus the PlanConfig surface: config
and legacy-kwarg compiles are equivalent, the deprecation fires exactly
once, and mixing both is an error.
"""
import sys
import warnings
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.core import formats as F  # noqa: E402
from repro.core import perfmodel as PM  # noqa: E402
from repro.core.eigensolver import as_apply, lanczos  # noqa: E402
from repro.core.matrices import power_law_rows  # noqa: E402
from repro.core.plan import SpMVPlan  # noqa: E402
from repro.core.planconfig import PlanConfig, coerce_config  # noqa: E402
from repro.serve.engine import BatchingSpMVServer  # noqa: E402

C = 8
N = 192


@pytest.fixture(scope="module")
def zipf():
    """Irregular rows: the matrix sigma-sorting exists for."""
    return power_law_rows(N, N, mean_nnz=6.0, seed=3, max_nnz=64)


@pytest.fixture(scope="module")
def x(zipf):
    return jnp.asarray(np.random.default_rng(0)
                       .standard_normal(zipf.shape[1]).astype(np.float32))


def _dense(m):
    return m.to_dense() if hasattr(m, "to_dense") else np.asarray(m)


SIGMAS = (1, C, 64, N)


# --- row-order preservation across the sigma grid ---------------------------

@pytest.mark.parametrize("sigma", SIGMAS)
def test_plan_output_is_in_original_row_order(zipf, x, sigma):
    ref = _dense(zipf) @ np.asarray(x)
    sell = F.SELL.from_csr(zipf, C=C, sigma=sigma)
    y = SpMVPlan.compile(sell, PlanConfig())(x)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("sigma", SIGMAS)
def test_sigma_values_agree_with_unsorted_pack(zipf, x, sigma):
    """Different windows, same answer (modulo f32 reassociation)."""
    y_sig = SpMVPlan.compile(F.SELL.from_csr(zipf, C=C, sigma=sigma),
                             PlanConfig())(x)
    y_id = SpMVPlan.compile(F.SELL.from_csr(zipf, C=C, sigma=1),
                            PlanConfig())(x)
    np.testing.assert_allclose(np.asarray(y_sig), np.asarray(y_id),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("sigma", SIGMAS)
def test_loop_oracle_agrees_per_sigma(zipf, x, sigma):
    """The chunk-by-chunk loop oracle sees the same permutation dataflow
    as the vectorized kernels."""
    sell = F.SELL.from_csr(zipf, C=C, sigma=sigma)
    y_auto = SpMVPlan.compile(sell, PlanConfig())(x)
    y_loop = SpMVPlan.compile(sell,
                              PlanConfig(backend="loop_reference"))(x)
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_loop),
                               rtol=2e-4, atol=2e-5)


def test_both_xla_formulations_preserve_row_order(zipf, x):
    """The dual-formulation XLA entry: the irregular Zipf pack streams
    flat, a regular (constant-row-length) pack keeps the padded views
    (no padding to save, and flat pays a second index stream) — both
    return rows in original order."""
    from repro.core.matrices import random_banded

    flat = F.SELL.from_csr(zipf, C=C, sigma=N)
    assert PM.sell_xla_uses_flat(flat)
    y = SpMVPlan.compile(flat, PlanConfig(backend="xla"))(x)
    np.testing.assert_allclose(np.asarray(y), _dense(zipf) @ np.asarray(x),
                               rtol=2e-4, atol=2e-5)

    band = random_banded(N, 4, 1.0, seed=0)
    padded = F.SELL.from_csr(band, C=C)
    assert not PM.sell_xla_uses_flat(padded)
    xb = jnp.asarray(np.random.default_rng(2)
                     .standard_normal(band.shape[1]).astype(np.float32))
    yb = SpMVPlan.compile(padded, PlanConfig(backend="xla"))(xb)
    np.testing.assert_allclose(np.asarray(yb), _dense(band) @ np.asarray(xb),
                               rtol=2e-4, atol=2e-5)


def test_permute_false_is_identity_window(zipf, x):
    cfg = PlanConfig(format="sell", permute=False)
    plan = SpMVPlan.compile(zipf, cfg)
    assert plan.matrix.sigma == 1
    perm = np.asarray(plan.matrix.perm).reshape(-1)
    n = zipf.shape[0]
    assert np.array_equal(perm[:n], np.arange(n))
    np.testing.assert_allclose(np.asarray(plan(x)),
                               _dense(zipf) @ np.asarray(x),
                               rtol=2e-4, atol=2e-5)


# --- quantized values: per-chunk scales follow the permutation --------------

@pytest.mark.parametrize("vd", ("f16", "bf16", "fp8_e4m3", "int8"))
@pytest.mark.parametrize("sigma", (1, 64, N))
def test_quantized_sigma_pack_matches_dense(zipf, x, vd, sigma):
    sell = F.with_value_dtype(F.SELL.from_csr(zipf, C=C, sigma=sigma), vd)
    y = SpMVPlan.compile(sell, PlanConfig())(x)
    ref = _dense(zipf) @ np.asarray(x)
    scale = max(1.0, float(np.abs(ref).max()))
    # quantization tolerance, not layout tolerance: a misrouted per-chunk
    # scale would be off by the chunk's magnitude, orders above this
    tol = {"f16": 2e-3, "bf16": 2e-2, "fp8_e4m3": 2e-1, "int8": 2e-2}[vd]
    assert float(np.abs(np.asarray(y) - ref).max()) / scale < tol


@pytest.mark.parametrize("vd", ("int8", "fp8_e4m3"))
def test_quantized_sigma_matches_quantized_loop_oracle(zipf, x, vd):
    """Bit-level routing check: the same quantized sigma-sorted container
    through the vectorized kernel and the loop oracle — any scale/perm
    mismatch shows up as a chunk-magnitude error."""
    sell = F.with_value_dtype(F.SELL.from_csr(zipf, C=C, sigma=64), vd)
    y_vec = SpMVPlan.compile(sell, PlanConfig())(x)
    y_loop = SpMVPlan.compile(sell, PlanConfig(backend="loop_reference"))(x)
    np.testing.assert_allclose(np.asarray(y_vec), np.asarray(y_loop),
                               rtol=2e-4, atol=2e-5)


# --- the serving fast path ---------------------------------------------------

def test_server_fast_path_with_sigma_config(zipf, x):
    # validate="off": the Zipf generator emits (summed) duplicate entries
    srv = BatchingSpMVServer(max_batch=1, validate="off")
    rep = srv.register("op", zipf,
                       config=PlanConfig(format="sell", sigma=64))
    assert rep.format == "sell"
    assert srv.plan("op").matrix.sigma == 64
    y = srv.spmv("op", x)
    np.testing.assert_allclose(np.asarray(y), _dense(zipf) @ np.asarray(x),
                               rtol=2e-4, atol=2e-5)
    # batched flush path composes with the permutation too
    fut = srv.submit("op", x)
    srv.flush("op")
    np.testing.assert_allclose(np.asarray(fut.result()),
                               _dense(zipf) @ np.asarray(x),
                               rtol=2e-4, atol=2e-5)


# --- PlanConfig equivalence + deprecation -----------------------------------

def test_config_and_legacy_kwargs_compile_the_same_plan(zipf):
    cfg_plan = SpMVPlan.compile(zipf, PlanConfig(format="sell", sigma=64))
    with pytest.deprecated_call():
        kw_plan = SpMVPlan.compile(zipf, format="sell", sigma=64)
    assert cfg_plan is kw_plan   # same conversion + memo key


def test_legacy_kwargs_warn_exactly_once(zipf):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        SpMVPlan.compile(zipf, format="sell", sigma=64)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "SpMVPlan.compile" in str(dep[0].message)


def test_config_plus_kwargs_is_an_error(zipf):
    with pytest.raises(ValueError, match="not both"):
        SpMVPlan.compile(zipf, PlanConfig(format="sell"), sigma=64)


def test_unknown_kwarg_is_a_typeerror(zipf):
    with pytest.raises(TypeError, match="unknown option"):
        SpMVPlan.compile(zipf, formt="sell")


def test_coerce_config_passthrough_identity():
    cfg = PlanConfig(format="sell", sigma=32)
    assert coerce_config(cfg, {}, api="t") is cfg
    with pytest.raises(TypeError, match="PlanConfig"):
        coerce_config({"format": "sell"}, {}, api="t")


def test_eigensolver_config_equivalence(zipf):
    cfg = PlanConfig(format="sell", sigma=64)
    e_cfg = lanczos(zipf, zipf.shape[0], m=12, config=cfg).eigenvalues[0]
    with pytest.deprecated_call():
        e_kw = lanczos(zipf, zipf.shape[0], m=12,
                       format="sell", sigma=64).eigenvalues[0]
    assert e_cfg == pytest.approx(e_kw, rel=1e-6)
    assert callable(as_apply(zipf, config=cfg))


def test_server_register_legacy_kwargs_deprecated(zipf, x):
    srv = BatchingSpMVServer(max_batch=1, validate="off")
    with pytest.deprecated_call():
        srv.register("legacy", zipf, format="sell", sigma=64)
    assert srv.plan("legacy").matrix.sigma == 64


def test_distributed_compile_config_api(zipf):
    from repro.core.distributed_plan import compile_distributed_spmv_plan
    plan = compile_distributed_spmv_plan(zipf, config=PlanConfig())
    xs = jnp.asarray(np.random.default_rng(1)
                     .standard_normal(zipf.shape[1]).astype(np.float32))
    np.testing.assert_allclose(np.asarray(plan(xs)),
                               _dense(zipf) @ np.asarray(xs),
                               rtol=2e-4, atol=2e-5)
    with pytest.deprecated_call():
        compile_distributed_spmv_plan(zipf, backend="xla")


# --- sigma autotune + defaults ----------------------------------------------

def test_select_sell_sigma_minimizes_pad_ratio(zipf):
    lens = zipf.row_lengths()
    sig, ratio = PM.select_sell_sigma(lens, C)
    for cand in PM.sell_sigma_candidates(zipf.shape[0], C):
        assert ratio <= PM.sell_pad_ratio(lens, C, cand) + 1e-12


def test_auto_format_records_chosen_sigma(zipf):
    """format="auto" with sigma=None autotunes the window and records the
    concrete int in the conversion kwargs the plan will execute."""
    choice = PM.select_format(zipf, backend="xla", sigma=None)
    best, _ = PM.select_sell_sigma(zipf.row_lengths(), C)
    if choice.format in ("sell", "hybrid"):
        assert choice.convert_kwargs.get("sigma") == int(best)
    plan = SpMVPlan.compile(zipf, PlanConfig(format="sell", backend="xla"))
    assert plan.matrix.sigma >= 1   # concrete resolved window on the pack


def test_one_default_sigma_source_of_truth():
    from repro.configs.holstein import HolsteinConfig
    from repro.core.planconfig import default_sell_sigma
    assert HolsteinConfig().sell_sigma == default_sell_sigma() \
        == F.DEFAULT_SELL_SIGMA


# --- the deprecated-kwarg lint gate -----------------------------------------

def test_check_deprecated_flags_and_passes(tmp_path):
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
    import check_deprecated as CD

    bad = tmp_path / "bad.py"
    bad.write_text("plan = SpMVPlan.compile(m, format='sell', sigma=64)\n")
    errs = CD.check_file(bad)
    assert len(errs) == 1 and "format" in errs[0] and "sigma" in errs[0]

    good = tmp_path / "good.py"
    good.write_text(
        "plan = SpMVPlan.compile(m, PlanConfig(format='sell'))\n"
        "srv.register('op', m, config=PlanConfig(sigma=64), max_batch=4)\n")
    assert CD.check_file(good) == []

    # the in-tree sources themselves are clean
    assert CD.main([]) == 0

# --- the un-permute epilogue -------------------------------------------------

def test_regular_matrix_sorts_to_identity_and_skips_unpermute():
    """An identity permutation means the kernels take the gather-free
    epilogue (`_perm_arg` returns None) — the regression behind the PR9
    serving-throughput fix.  sigma=1 packs never reorder, and a matrix
    whose row lengths are already non-increasing sorts to the identity
    even with the full-window sort."""
    from repro.core.matrices import random_banded
    from repro.kernels.sell import _perm_arg, sell_perm_is_natural

    band = random_banded(N, 4, 1.0, seed=0)
    m = F.SELL.from_csr(band, C=C, sigma=1)   # no reordering by construction
    assert sell_perm_is_natural(m)
    assert _perm_arg(m) is None

    # constant row length: every row has exactly 3 nonzeros (tridiagonal
    # with wraparound), so even sigma=N sorting is stable-identity
    dense = np.zeros((N, N))
    diag = np.arange(N)
    dense[diag, (diag - 1) % N] = 1.0
    dense[diag, diag] = 1.0
    dense[diag, (diag + 1) % N] = 1.0
    mc = F.SELL.from_csr(F.CSR.from_dense(dense), C=C, sigma=N)
    assert sell_perm_is_natural(mc)
    assert _perm_arg(mc) is None

    srt = F.SELL.from_csr(power_law_rows(N, N, mean_nnz=6.0, seed=3,
                                         max_nnz=64), C=C, sigma=N)
    assert not sell_perm_is_natural(srt)
    inv = np.asarray(_perm_arg(srt))
    # inverse-permutation gather: perm[inv[i]] == i for every real row
    assert (np.asarray(srt.perm)[inv] == np.arange(N)).all()


def test_flat_overhead_gates_the_formulation_pick():
    """The flat segment-sum formulation is charged its measured execution
    overhead: a mildly padded pack stays padded on cpu even though its raw
    flat bytes are smaller, while family "tpu" (overhead 1.0) switches on
    bytes alone."""
    assert PM.sell_flat_overhead("cpu") > PM.sell_flat_overhead("tpu") == 1.0

    from repro.core.matrices import holstein_hubbard_surrogate
    m = F.SELL.from_csr(holstein_hubbard_surrogate(512, seed=0),
                        C=C, sigma=256)
    flat = int(np.asarray(m.val).shape[0])
    cw = np.asarray(m.chunk_width)
    padded = int(m.n_chunks * int(cw.max()) * m.C)
    assert flat * 12 < padded * 8          # raw flat bytes win at f32...
    assert not PM.sell_xla_uses_flat(m, "cpu")   # ...but the overhead gates
    assert PM.sell_xla_uses_flat(m, "tpu")
