"""Distributed SpMV plans: variant comparison on an emulated device mesh.

Run with forced host devices to see a real mesh on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_spmv.py

Compiles the Holstein-Hubbard surrogate into all three distributed plan
variants (allgather / ring / overlap), checks them against the dense
reference, prints the model's per-partition slab choices and traffic
accounting, then runs a sharded Lanczos ground-state solve through the
same plan — the paper's host application, distributed with no solver
changes.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spmv as S
from repro.core.distributed import make_mesh_1d
from repro.core.distributed_plan import VARIANTS, plan_all_variants
from repro.core.eigensolver import ground_state_energy
from repro.core.matrices import holstein_hubbard_surrogate


def main(n: int = 6000) -> None:
    print(f"devices: {len(jax.devices())}  ({jax.default_backend()})")
    m = holstein_hubbard_surrogate(n, seed=0)
    mesh = make_mesh_1d()
    x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
    y_ref = np.asarray(S.csr_spmv(m, x))

    plans = plan_all_variants(m, mesh)
    print(f"\n{'variant':<10} {'slab':<5} {'imbal':>6} {'local':>6} "
          f"{'coll MB':>8} {'ms/SpMV':>8} {'rel err':>9}")
    for variant in VARIANTS:
        plan = plans[variant]
        jax.block_until_ready(plan(x))
        t0 = time.perf_counter()
        for _ in range(10):
            y = plan(x)
        jax.block_until_ready(y)
        dt = (time.perf_counter() - t0) / 10
        err = float(np.max(np.abs(np.asarray(y) - y_ref)) / np.max(np.abs(y_ref)))
        print(f"{variant:<10} {plan.slab_format:<5} {plan.imbalance:>6.3f} "
              f"{plan.local_fraction:>6.2f} {plan.traffic['collective'] / 1e6:>8.2f} "
              f"{dt * 1e3:>8.3f} {err:>9.2e}")

    print("\nper-partition model choices (overlap plan):")
    for r in plans["overlap"].shard_reports:
        print(f"  shard {r.part}: rows={r.rows} nnz={r.nnz} "
              f"local={r.local_nnz / max(1, r.nnz):.2f} -> {r.format}")

    e0 = ground_state_energy(plans["overlap"], n, m=60)
    print(f"\nsharded Lanczos ground state (overlap plan): {e0:.6f}")


if __name__ == "__main__":
    main()
