"""End-to-end driver #3: serve an LM whose FFN weights are sparse —
the paper's formats applied to the modern decode-MVM regime.

1. Initialize a small LM; magnitude-prune its FFN weights block-wise.
2. Wrap them in SparseLinear (the format advisor picks BSR vs SELL).
3. Compare dense vs sparse-kernel FFN outputs + the modelled bytes/token.
4. Generate tokens through the engine.

    PYTHONPATH=src python examples/serve_sparse.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced
from repro.core.perfmodel import TPU_FP32
from repro.models.registry import Model, get_config
from repro.models.sparse import SparseLinear, magnitude_prune, sparsity_report
from repro.serve.engine import Engine, GenerationConfig

cfg = reduced(get_config("qwen3-0.6b"), d_model=128, d_ff=512, n_layers=2)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

# --- sparsify one FFN weight and compare dense vs kernel path ------------
w = np.asarray(params["units"]["mlp"]["wi_gate"][0]).T  # (d_ff, d_model)
w_sparse = magnitude_prune(w, density=0.25, structured=(8, 128))
rep = sparsity_report(w_sparse)
print(f"[sparse] FFN weight {w.shape}: density=25% block(8,128) "
      f"-> advisor: {rep['advised_format']}")
lin = SparseLinear.from_dense(w_sparse, fmt="auto", backend="ref")
x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.d_model), jnp.float32)
y_sparse = lin(x)
y_dense = x @ jnp.asarray(w_sparse).T
err = float(jnp.abs(y_sparse - y_dense).max())
print(f"[sparse] kernel-vs-dense max err = {err:.2e}; "
      f"streamed ~{lin.streamed_bytes(TPU_FP32)/1e3:.1f} KB/SpMV "
      f"vs dense {w.size*4/1e3:.1f} KB")

# --- generate through the engine -------------------------------------------
eng = Engine(model, params, batch_size=2, max_len=64)
prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
outs = eng.generate(prompts, GenerationConfig(max_new_tokens=12))
for i, o in enumerate(outs):
    print(f"[serve] request {i}: {o}")
print(f"[serve] ~{eng.decode_bytes_per_token()/1e6:.2f} MB streamed per token "
      f"(weights + cache/slot) — the decode-MVM bandwidth regime")
