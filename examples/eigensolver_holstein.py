"""End-to-end driver #1: the paper's application — sparse eigensolver.

1. Build the *exact* Holstein-Hubbard Hamiltonian (small, validated against
   dense diagonalization), then the pattern-faithful surrogate at scale.
2. Benchmark every storage format on the surrogate.
3. Run Lanczos to convergence through the best format — SpMVM is >99 % of
   the runtime, as the paper states.
4. Optionally distribute the SpMV over all local devices (shard_map).

    PYTHONPATH=src python examples/eigensolver_holstein.py [--n 50000]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as D
from repro.core import formats as F
from repro.core import spmv as S
from repro.core.eigensolver import lanczos
from repro.core.matrices import (HolsteinHubbardParams, holstein_hubbard_exact,
                                 holstein_hubbard_surrogate)
from repro.core.plan import SpMVPlan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--lanczos-steps", type=int, default=64)
    args = ap.parse_args()

    # --- 1a. exact model, validated against dense eigh -------------------
    p = HolsteinHubbardParams(L=3, n_up=1, n_dn=1, max_phonon=2, g=0.5, U=4.0)
    hh = holstein_hubbard_exact(p)
    e_dense = float(np.linalg.eigvalsh(hh.to_dense())[0])
    res = lanczos(S.make_spmv(hh), hh.shape[0], m=60, dtype=jnp.float32)
    print(f"[exact] dim={hh.shape[0]} E0(lanczos)={res.eigenvalues[0]:.8f} "
          f"E0(dense)={e_dense:.8f} |diff|={abs(res.eigenvalues[0]-e_dense):.2e}")

    # --- 1b. surrogate at scale -------------------------------------------
    m = holstein_hubbard_surrogate(args.n, seed=0)
    print(f"[surrogate] N={args.n} nnz={m.nnz}")

    # --- 2. format shoot-out (compiled plans: preprocess once per format) ---
    x = jax.random.normal(jax.random.PRNGKey(0), (args.n,), jnp.float32)
    best_name, best_t, best_fn = None, np.inf, None
    for name, obj in [("csr", m), ("ell", F.ELL.from_csr(m)),
                      ("jds", F.JDS.from_csr(m)),
                      ("sell", F.SELL.from_csr(m, C=8, sigma=1024)),
                      ("hybrid", F.split_dia(m))]:
        f = SpMVPlan.compile(obj)
        jax.block_until_ready(f(x))
        t0 = time.perf_counter()
        for _ in range(3):
            y = f(x)
        jax.block_until_ready(y)
        t = (time.perf_counter() - t0) / 3
        print(f"  {name:7s} {2*m.nnz/t/1e9:7.2f} GFLOP/s ({t*1e3:.2f} ms) "
              f"[{f.report.kernel}]")
        if t < best_t:
            best_name, best_t, best_fn = name, t, f

    # --- 3. Lanczos through the winner --------------------------------------
    print(f"[lanczos] using {best_name}")
    t0 = time.perf_counter()
    res = lanczos(best_fn, args.n, m=args.lanczos_steps, dtype=jnp.float32)
    dt = time.perf_counter() - t0
    spmv_t = res.n_spmv * best_t
    print(f"  E0={res.eigenvalues[0]:.6f} ({res.n_spmv} SpMVs, {dt:.2f}s total, "
          f"~{100*spmv_t/dt:.0f}% in SpMV)")

    # --- 4. distributed SpMV over local devices (per-shard plans) -----------
    dist = D.compile_distributed_plan(m, strategy="allgather", balance="nnz")
    err = float(jnp.abs(dist(x) - best_fn(x)).max())
    print(f"[distributed] {dist.parts} device(s), {dist.strategy} variant, "
          f"imbalance={dist.imbalance:.3f}, max |diff| vs serial = {err:.2e}")


if __name__ == "__main__":
    main()
