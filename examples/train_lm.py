"""End-to-end driver #2: train a ~100M-param LM for a few hundred steps.

Uses the qwen3 family at a ~100M scale (same architecture, reduced depth/
width), the WSD schedule, checkpointing, and deterministic resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300
(about 100M params; use --tiny for a quick CI-sized run)
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.data.pipeline import pipeline_for
from repro.models.registry import Model, get_config
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config("qwen3-0.6b")
    if args.tiny:
        from repro.configs import reduced
        cfg = reduced(cfg)
    else:
        # ~100M-param variant of the qwen3 family
        cfg = dataclasses.replace(
            cfg, n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
            d_ff=1536, vocab=32768, remat="none", q_chunk=256, k_chunk=256)
    model = Model(cfg)
    print(f"[train_lm] {model.total_params()/1e6:.1f}M params "
          f"({model.active_params()/1e6:.1f}M active)")

    pipe = pipeline_for(cfg, shape_batch=args.batch, seq_len=args.seq)
    opt = OptimizerConfig(lr=6e-4, schedule="wsd", warmup_steps=args.steps // 10,
                          total_steps=args.steps, decay_frac=0.2)
    loop = TrainLoop(model, opt,
                     TrainLoopConfig(total_steps=args.steps, log_every=20,
                                     ckpt_every=max(50, args.steps // 4),
                                     ckpt_dir=args.ckpt_dir),
                     pipe)
    loop.run()
    losses = [l for _, l, _ in loop.history]
    print(f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
