"""End-to-end driver #4: micro-batched SpMV serving under synthetic load.

An open-loop load generator (arrivals don't wait for completions — the
regime where batching matters) drives ``BatchingSpMVServer`` at two traffic
rates against the same operator:

* **heavy** traffic fills batches before the deadline: width-driven
  flushes, near-zero padding, throughput approaching the SpMM roofline;
* **thin** traffic never fills a batch: deadline-driven flushes keep
  latency bounded, and the padding ratio records the price.

Arrivals are a deterministic Poisson process on a *virtual* clock (the
server's ``clock`` is injectable), so the example's queue dynamics —
flush reasons, batch widths, padding — are exactly reproducible; only the
reported wall-clock throughput varies with the host.

    PYTHONPATH=src python examples/serving_load.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import formats as F
from repro.core.matrices import holstein_hubbard_surrogate
from repro.serve import BatchingSpMVServer


class VirtualClock:
    """The simulation's time source; the generator advances it by hand."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def run_load(name, rate_qps, n_requests, deadline_s, matrix, xs):
    """Drive one open-loop run; returns (stats, latencies, wall_s)."""
    clock = VirtualClock()
    srv = BatchingSpMVServer(deadline_s=deadline_s, clock=clock)
    srv.register(name, matrix)
    width = srv.stats()[name]["batch_width"]

    rng = np.random.default_rng(42)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, n_requests))
    inflight = []  # (t_arrival, future)
    latencies = []

    def drain():
        done = [(t0, f) for t0, f in inflight if f.done()]
        for t0, _ in done:
            latencies.append(clock.t - t0)
        inflight[:] = [(t0, f) for t0, f in inflight if not f.done()]

    t_wall = time.perf_counter()
    for t_arr, x in zip(arrivals, xs[:n_requests]):
        # advance virtual time to the arrival, flushing overdue batches
        # on the way (the cooperative stand-in for a flusher thread)
        clock.t = float(t_arr)
        srv.pump()
        drain()
        inflight.append((clock.t, srv.submit(name, x)))
        drain()
    clock.t = float(arrivals[-1]) + deadline_s
    srv.pump()
    srv.flush(name)
    drain()
    jax.block_until_ready([f.result() for _, f in inflight] or [0])
    wall_s = time.perf_counter() - t_wall

    st = srv.stats()[name]
    lat = np.array(latencies)
    print(f"[{name}] rate={rate_qps:g} req/s  policy width={width} "
          f"deadline={deadline_s*1e3:g} ms")
    print(f"    {st['requests']} requests in {st['batches']} batches, "
          f"mean width {st['mean_batch_width']:.2f}, "
          f"padding ratio {st['padding_ratio']:.2f}")
    print(f"    queueing latency (virtual): p50={np.percentile(lat, 50)*1e3:.2f} ms "
          f"p95={np.percentile(lat, 95)*1e3:.2f} ms")
    print(f"    wall-clock service throughput: {st['requests']/wall_s:.0f} req/s")
    return st


n = 3000
m = holstein_hubbard_surrogate(n, seed=0)
sell = F.convert(m, "sell", C=8)
rng = np.random.default_rng(0)
xs = [np.asarray(rng.standard_normal(n), np.float32) for _ in range(240)]

# heavy traffic: arrivals far faster than the deadline -> width flushes
heavy = run_load("heavy", rate_qps=50_000, n_requests=240,
                 deadline_s=2e-3, matrix=sell, xs=xs)
# thin traffic: the deadline fires long before a batch fills
thin = run_load("thin", rate_qps=500, n_requests=60,
                deadline_s=2e-3, matrix=sell, xs=xs)

assert heavy["mean_batch_width"] > thin["mean_batch_width"]
assert thin["padding_ratio"] > heavy["padding_ratio"]
print("[load] heavy traffic batches wide; thin traffic trades padding "
      "for bounded latency — the flush policy working as designed")
