"""Quickstart: the paper's pipeline in 40 lines.

Builds the Holstein-Hubbard matrix, asks the performance model for the best
storage format, runs the SpMV through the chosen kernel, and computes the
ground-state energy with Lanczos — the full loop of the paper.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import perfmodel as PM
from repro.core.eigensolver import lanczos
from repro.core.matrices import holstein_hubbard_surrogate
from repro.core.plan import SpMVPlan

# 1. the paper's test matrix (scaled down for a quick run)
n = 20_000
m = holstein_hubbard_surrogate(n, seed=0)
stats = F.matrix_stats(m)
print(f"matrix: N={n}, nnz={m.nnz}, {stats['nnz_per_row_mean']:.1f} nnz/row, "
      f"{stats['frac_nnz_top12_diags']:.0%} of nnz in 12 diagonals")

# 2. ask the performance model for the best format (paper Sec. 1 goal)
advice = PM.advise(stats, m.row_lengths(), am=PM.TPU_FP32)
best = advice["_best"]
print("format advisor says:", best)
for name, p in advice.items():
    if name != "_best":
        print(f"  {name:7s} balance={p.balance_bytes_per_flop:5.2f} B/F "
              f"-> predicted {p.gflops:6.1f} GFLOP/s on TPU v5e")

# 3. convert + compile an execution plan (preprocess once, run many times)
obj = F.convert(m, best if best != "csr" else "sell", C=8)
plan = SpMVPlan.compile(obj)
print(f"plan: kernel={plan.report.kernel} "
      f"balance={plan.report.balance_bytes_per_flop:.2f} B/F")
x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
y = plan(x)
print("SpMV ok:", y.shape, "||y|| =", float(jnp.linalg.norm(y)))

# 4. the host application: Lanczos ground state (SpMV is >99% of the work);
#    the plan is reused across every iteration
res = lanczos(plan, n, m=48, dtype=jnp.float32)
print(f"Lanczos: E0 = {res.eigenvalues[0]:.6f} after {res.n_spmv} SpMVs")
