"""End-to-end driver: matrix-free Lanczos on the 3-D Laplacian.

The 7-point stencil is the paper's best case for generated operators:
every diagonal's offset is a function of the grid shape and every value
is a constant, so the SpMV needs *no* matrix arrays at all — the kernel
computes ``col = row + offset`` and the stencil weights in-registers and
streams only the vectors.

1. Detect the ``MatrixFreeOperator`` descriptor from the assembled CSR
   (exact detection: the descriptor materializes back bitwise-identical).
2. Compare the perfmodel's byte accounting: materialized CSR stream vs
   the zero-index-bytes descriptor stream.
3. Time both plans and convert the measured time into achieved bytes/nnz
   through the host's calibrated STREAM bandwidth — the model-vs-measured
   receipt for the traffic the format deletes.
4. Run Lanczos to the ground state through the matrix-free plan.

    PYTHONPATH=src python examples/matrix_free_laplacian.py [--nx 24]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")   # benchmarks.common (host STREAM calibration)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import host_chip
from repro.core import formats as F
from repro.core import perfmodel as PM
from repro.core.eigensolver import lanczos
from repro.core.matrices import laplacian_3d
from repro.core.plan import SpMVPlan
from repro.core.planconfig import PlanConfig


def _time(plan, x, iters=50):
    jax.block_until_ready(plan(x))
    t0 = time.perf_counter()
    y = None
    for _ in range(iters):
        y = plan(x)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / iters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=24, help="grid points per axis")
    ap.add_argument("--lanczos-steps", type=int, default=64)
    args = ap.parse_args()

    # --- 1. assemble once, detect the descriptor -------------------------
    m = F.with_value_dtype(laplacian_3d(args.nx, args.nx, args.nx), "f32")
    op = F.detect_matrix_free(m)
    assert op is not None, "the 7-point stencil must detect as matrix-free"
    print(f"[detect] N={m.shape[0]} nnz={m.nnz} -> {op.n_diags} diagonals, "
          f"{op.n_generated} generated / {op.n_stored} stored "
          f"(container streams {'nothing' if op.data is None else 'stored lanes only'})")
    back = F.materialize(op)
    assert np.array_equal(np.asarray(back.val), np.asarray(m.val))

    # --- 2. model-side byte accounting ------------------------------------
    bytes_csr = PM.spmv_streamed_bytes(m) / m.nnz
    bytes_mf = PM.spmv_streamed_bytes(op) / m.nnz
    print(f"[model] streamed bytes/nnz: csr={bytes_csr:.2f} "
          f"matrix_free={bytes_mf:.2f} "
          f"(predicted saving {bytes_csr - bytes_mf:.2f} B/nnz)")

    # --- 3. measured traffic through the calibrated roofline ---------------
    chip = host_chip()
    x = jax.random.normal(jax.random.PRNGKey(0), (m.shape[0],), jnp.float32)
    plan_csr = SpMVPlan.compile(m, PlanConfig(format="csr", chip=chip))
    plan_mf = SpMVPlan.compile(m, PlanConfig(format="matrix_free", chip=chip))
    t_csr, t_mf = _time(plan_csr, x), _time(plan_mf, x)
    bw = chip.hbm_bytes_per_s
    print(f"[measured] csr        : {t_csr*1e3:7.3f} ms  "
          f"~{t_csr*bw/m.nnz:6.2f} B/nnz moved at STREAM bw")
    print(f"[measured] matrix_free: {t_mf*1e3:7.3f} ms  "
          f"~{t_mf*bw/m.nnz:6.2f} B/nnz moved at STREAM bw  "
          f"({t_csr/t_mf:.2f}x)")
    err = float(jnp.max(jnp.abs(plan_mf(x) - plan_csr(x))))
    print(f"[parity] max |diff| vs csr plan = {err:.2e}")

    # --- 4. ground state through the matrix-free plan ----------------------
    t0 = time.perf_counter()
    res = lanczos(plan_mf.spmv, m.shape[0], m=args.lanczos_steps,
                  dtype=jnp.float32)
    dt = time.perf_counter() - t0
    print(f"[lanczos] E0={res.eigenvalues[0]:.6f} "
          f"({res.n_spmv} matrix-free SpMVs, {dt:.2f}s; "
          f"continuum ground state -> 3*pi^2/(nx+1)^2 per unit h^2)")


if __name__ == "__main__":
    main()
