"""Docs gate for CI: markdown link check + doctest over doc code snippets.

Two failure modes docs rot into, both cheap to machine-check:

* **dead relative links/paths** — every ``[text](target)`` whose target is
  not an URL or a pure anchor must resolve to a file or directory in the
  repo (anchors are stripped before the existence check);
* **stale code snippets** — every ``>>>`` example in the checked files is
  executed with doctest (run with ``PYTHONPATH=src`` so snippets can
  import ``repro``).

Usage::

    PYTHONPATH=src python tools/check_docs.py README.md docs/*.md
"""
from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

# [text](target) excluding images' inner part handled identically; ignore
# targets with a scheme (http:, https:, mailto:) and pure #anchors
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links(path: Path, repo_root: Path) -> list[str]:
    """Return human-readable errors for dead relative links in ``path``."""
    errors = []
    for target in _LINK_RE.findall(path.read_text()):
        if re.match(r"^[a-zA-Z][a-zA-Z0-9+.-]*:", target) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            errors.append(f"{path}: dead link -> {target}")
        elif repo_root not in resolved.parents and resolved != repo_root:
            errors.append(f"{path}: link escapes the repo -> {target}")
    return errors


def check_doctests(path: Path) -> list[str]:
    """Run every ``>>>`` snippet in ``path``; return failure summaries."""
    try:
        results = doctest.testfile(
            str(path), module_relative=False, verbose=False,
            optionflags=doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE)
    except Exception as e:  # snippet raised outside an expected-output check
        return [f"{path}: doctest crashed: {type(e).__name__}: {e}"]
    if results.failed:
        return [f"{path}: {results.failed}/{results.attempted} doctest(s) failed"]
    return []


def main(argv: list[str]) -> int:
    files = [Path(a) for a in argv] or [Path("README.md")]
    repo_root = Path(__file__).resolve().parent.parent
    errors = []
    attempted = 0
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file does not exist")
            continue
        errors += check_links(f, repo_root)
        errors += check_doctests(f)
        attempted += 1
    for e in errors:
        print(f"FAIL {e}", file=sys.stderr)
    print(f"checked {attempted} file(s): "
          f"{'OK' if not errors else f'{len(errors)} error(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
