"""Lint gate: no new in-tree call sites may use the deprecated bare
compile kwargs.

PR9 unified every plan-compile surface behind ``config=PlanConfig(...)``;
the historical bare kwargs (``format=``, ``backend=``, ``sigma=``, ...)
remain as runtime ``DeprecationWarning`` aliases for downstream users, but
the repo's own code must not keep minting them — otherwise the migration
never converges.  This checker walks the AST of every Python file under
``src/``, ``benchmarks/`` and ``examples/`` (``tests/`` is exempt: the
deprecated path itself is under test there) and fails on any call to a
compile entry point that passes a ``PlanConfig`` field as a bare keyword.

Flagged entry points (by callable name, so both ``SpMVPlan.compile`` and
``plan.compile`` forms are caught):

* attribute calls: ``.compile(...)``, ``.register(...)``,
  ``.register_distributed(...)``
* plain calls: ``compile_plan``, ``compile_distributed_spmv_plan``,
  ``as_apply``, ``lanczos``, ``ground_state_energy``, ``spectral_extent``

Usage::

    python tools/check_deprecated.py [paths...]
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent


def _load_fields() -> tuple[str, ...]:
    """Read ``_FIELDS`` out of planconfig.py by AST, not by import —
    the CI lint job runs this without jax installed."""
    src = (_REPO / "src" / "repro" / "core" / "planconfig.py").read_text()
    for node in ast.walk(ast.parse(src)):
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "_FIELDS"
                        for t in node.targets)):
            return tuple(ast.literal_eval(node.value))
    raise RuntimeError("planconfig.py: _FIELDS assignment not found")


_FIELDS = _load_fields()

DEFAULT_ROOTS = ("src", "benchmarks", "examples")

#: ``obj.<name>(...)`` calls subject to the check
ATTR_CALLS = {"compile", "register", "register_distributed"}

#: bare ``<name>(...)`` calls subject to the check
NAME_CALLS = {"compile_plan", "compile_distributed_spmv_plan",
              "as_apply", "lanczos", "ground_state_energy",
              "spectral_extent"}


def _callee_name(node: ast.Call) -> str | None:
    f = node.func
    if isinstance(f, ast.Attribute) and f.attr in ATTR_CALLS:
        return f.attr
    if isinstance(f, ast.Name) and f.id in NAME_CALLS:
        return f.id
    return None


def check_file(path: Path) -> list[str]:
    """Human-readable violations for one Python source file."""
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:  # a broken file is its own CI failure
        return [f"{path}:{e.lineno}: syntax error: {e.msg}"]
    errors = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _callee_name(node)
        if name is None:
            continue
        bad = sorted(kw.arg for kw in node.keywords
                     if kw.arg in _FIELDS)
        if bad:
            errors.append(
                f"{path}:{node.lineno}: {name}(...) passes deprecated bare "
                f"kwarg(s) {bad}; use config=PlanConfig(...)")
    return errors


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    repo = Path(__file__).resolve().parent.parent
    roots = [Path(a) for a in args] or [repo / r for r in DEFAULT_ROOTS]
    files = []
    for root in roots:
        if root.is_file():
            files.append(root)
        else:
            files.extend(sorted(root.rglob("*.py")))
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(e)
    print(f"check_deprecated: {len(files)} files, {len(errors)} violation(s)",
          file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
