"""Perf-regression gate for CI: fresh BENCH artifact vs committed baseline.

The BENCH_PR*.json trajectory (see docs/BENCHMARKS.md) was write-only until
PR 4; this tool makes it an enforced contract.  It extracts every
higher-is-better metric that the fresh artifact and the committed baseline
*share* (plan-bench per-format GFlop/s, distributed variant GFlop/s,
serving throughput + speedups, corpus sweep GFlop/s — artifacts from
different PRs overlap only where their schemas do), forms the per-metric
ratio new/old, and fails when the **geometric mean** ratio drops below
``1 - tolerance``.

Geomean-with-tolerance is deliberate: single metrics on shared CPU runners
are noisy (the committed baseline was produced on different hardware), but
a fleet-wide geomean sliding more than 25% is a real regression, not
scheduler jitter.  Individual metric drops are reported but only warn.

Known limitation: most gated metrics are *absolute* throughputs, so the
comparison is only meaningful between machines of the same class — the
tolerance absorbs runner-to-runner spread, not a hardware generation gap.
Regenerate and commit the baseline from the same runner class as CI (the
lineage in docs/BENCHMARKS.md does exactly this), or widen --tolerance
when the runner fleet changes.

Besides the relative geomean gate, ``--bound`` asserts *absolute*
invariants on the fresh artifact alone — machine-independent ratios the
baseline comparison cannot express (e.g. the serving guardrails must cost
at most 5% throughput: ``--bound "serving/guardrails/overhead_ratio<=1.05"``).
The path navigates the nested JSON with ``/`` separators; a missing path
fails the gate (an invariant that silently stops being measured is itself
a regression).

Usage::

    PYTHONPATH=src python tools/check_bench.py \
        --new BENCH_PR6.json --baseline BENCH_PR5.json --tolerance 0.25 \
        --bound "serving/guardrails/overhead_ratio<=1.05" \
        --summary-file "$GITHUB_STEP_SUMMARY"

Exit code 1 = regression (build fails), 0 = within tolerance.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from dataclasses import dataclass, field

#: leaf-key names that are throughput-like (higher is better).  Timings,
#: balances and ratios-to-model are deliberately absent: lower-is-better
#: and diagnostic fields must not enter the gate.
HIGHER_BETTER_KEYS = frozenset({
    "gflops",
    "gflops_planned",
    "gflops_naive",
    "qps",
    "speedup_plan_vs_naive",
    "speedup_vs_sequential",
    "speedup_at_width8",
    "kernel_speedup_at_width8",
    "speedup_vs_f32",
    # measured-autotuning tier: how much the warm (DB) pick beats the
    # cold model pick; >= 1.0 by construction when the DB is fresh
    "tuned_speedup_vs_model",
    # matrix-free tier: generated-operator plan vs the best *measured*
    # materialized plan on the same matrix
    "speedup_vs_materialized",
})


def extract_metrics(payload: dict, prefix: str = "") -> dict[str, float]:
    """Flatten an artifact to {path: value} over the gated metric keys.

    Walks nested dicts; a leaf enters the result when its key is in
    ``HIGHER_BETTER_KEYS`` and its value is a positive finite number
    (zero/negative/NaN values cannot form a meaningful ratio).
    """
    out: dict[str, float] = {}
    for key, value in payload.items():
        path = f"{prefix}/{key}" if prefix else str(key)
        if isinstance(value, dict):
            out.update(extract_metrics(value, path))
        elif key in HIGHER_BETTER_KEYS and isinstance(value, (int, float)):
            v = float(value)
            if math.isfinite(v) and v > 0:
                out[path] = v
    return out


@dataclass
class Comparison:
    """Outcome of ``compare``: the verdict plus everything behind it."""

    ok: bool
    geomean_ratio: float          # new/baseline over the shared metrics
    tolerance: float
    n_shared: int
    ratios: dict = field(default_factory=dict)      # {metric: new/old}
    regressions: dict = field(default_factory=dict)  # metrics below 1 - tol

    def summary_line(self) -> str:
        verdict = "OK" if self.ok else "REGRESSION"
        return (f"perf gate {verdict}: geomean {self.geomean_ratio:.3f}x "
                f"vs baseline over {self.n_shared} shared metrics "
                f"(tolerance {self.tolerance:.0%}, "
                f"{len(self.regressions)} metric(s) individually below)")


def compare(new: dict, baseline: dict, tolerance: float = 0.25) -> Comparison:
    """Gate a fresh artifact payload against a baseline payload.

    Args:
        new: parsed fresh artifact (e.g. BENCH_PR4.json just produced).
        baseline: parsed committed baseline (e.g. BENCH_PR3.json).
        tolerance: allowed fractional drop of the geomean ratio (0.25 =
            fail below 0.75x) — headroom for CPU-runner noise.

    Returns:
        A ``Comparison``; ``ok`` is False when the geomean of new/old over
        the shared higher-is-better metrics falls below ``1 - tolerance``.
        With no shared metrics the gate passes vacuously (a schema change
        should not block the build) but reports ``n_shared == 0``.
    """
    m_new = extract_metrics(new)
    m_old = extract_metrics(baseline)
    shared = sorted(set(m_new) & set(m_old))
    ratios = {k: m_new[k] / m_old[k] for k in shared}
    if ratios:
        geomean = math.exp(sum(math.log(r) for r in ratios.values()) / len(ratios))
    else:
        geomean = 1.0
    floor = 1.0 - tolerance
    regressions = {k: r for k, r in ratios.items() if r < floor}
    return Comparison(
        ok=geomean >= floor,
        geomean_ratio=geomean,
        tolerance=tolerance,
        n_shared=len(shared),
        ratios=ratios,
        regressions=regressions,
    )


def lookup_path(payload: dict, path: str) -> float:
    """Resolve a ``/``-separated path to a numeric leaf of the artifact.

    Raises KeyError (missing key / non-dict intermediate) or TypeError
    (non-numeric leaf) — both mean the bound cannot be checked, which the
    gate treats as a failure, not a skip.
    """
    node = payload
    for part in path.split("/"):
        if not isinstance(node, dict) or part not in node:
            raise KeyError(f"path {path!r} not found in artifact (at {part!r})")
        node = node[part]
    if isinstance(node, bool) or not isinstance(node, (int, float)):
        raise TypeError(f"path {path!r} is not numeric: {node!r}")
    return float(node)


def check_bound(payload: dict, spec: str) -> tuple[bool, str]:
    """Evaluate one ``--bound`` spec ("path<=value" or "path>=value").

    Returns (ok, human-readable line).  A malformed spec raises ValueError
    at parse time; an unresolvable path reports ok=False (see
    ``lookup_path``).
    """
    for op in ("<=", ">="):
        if op in spec:
            path, _, raw = spec.partition(op)
            path, raw = path.strip(), raw.strip()
            try:
                limit = float(raw)
            except ValueError:
                raise ValueError(f"bound {spec!r}: limit {raw!r} is not a number")
            try:
                value = lookup_path(payload, path)
            except (KeyError, TypeError) as e:
                return False, f"bound FAILED  {spec} ({e})"
            ok = value <= limit if op == "<=" else value >= limit
            verdict = "ok" if ok else "FAILED"
            return ok, f"bound {verdict:6s}  {path} = {value:.4f} {op} {limit}"
    raise ValueError(f"bound {spec!r}: expected 'path<=value' or 'path>=value'")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--new", required=True, help="fresh artifact JSON path")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON path")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed geomean drop (default 0.25 for CPU noise)")
    ap.add_argument("--summary-file", default=None,
                    help="append the one-line verdict here (e.g. "
                         "$GITHUB_STEP_SUMMARY)")
    ap.add_argument("--bound", action="append", default=[],
                    help="absolute invariant on the fresh artifact, "
                         "'path<=value' or 'path>=value' with /-separated "
                         "path (repeatable); a missing path fails the gate")
    args = ap.parse_args(argv)

    with open(args.new) as fh:
        new = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    cmp = compare(new, baseline, tolerance=args.tolerance)

    lines = [cmp.summary_line()]
    print(lines[0])
    if cmp.n_shared == 0:
        print("note: artifacts share no metrics; nothing to gate on")
    worst = sorted(cmp.ratios.items(), key=lambda kv: kv[1])[:8]
    for k, r in worst:
        marker = "REGRESSED" if k in cmp.regressions else "ok"
        print(f"  {r:6.2f}x  {marker:9s} {k}")

    bounds_ok = True
    for spec in args.bound:
        ok, line = check_bound(new, spec)
        bounds_ok &= ok
        lines.append(line)
        print(line)

    if args.summary_file:
        with open(args.summary_file, "a") as fh:
            fh.write("\n".join(lines) + "\n")
    return 0 if (cmp.ok and bounds_ok) else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
